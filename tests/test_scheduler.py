"""The overlapped continuous-batching scheduler: bit-exact vs the serial
reference, dead slots inert, and the no-retrace guarantee pinned by trace
counters."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.comm import (Agent, CommSession, InMemoryTransport,
                        RemoteTransport, SerializedTransport)
from repro.comm.resilience import RetryPolicy
from repro.core.protocol import TRACE_COUNTS
from repro.core.types import KVCommConfig
from repro.data.synthetic import SyntheticTask, TaskConfig
from repro.models import transformer as tfm
from repro.serving.scheduler import (Scheduler, SchedulerConfig,
                                     make_requests, serve_serial)


def _session(tiny_cfg, tok, transport):
    cfg = dataclasses.replace(tiny_cfg, vocab_size=tok.vocab_size)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    return CommSession(Agent("s", cfg, params, tok),
                       Agent("r", cfg, params, tok), transport), cfg, params


def _stream(tok, n=6, max_new=(4, 2, 1)):
    """Mixed-length request stream: ragged contexts AND ragged budgets."""
    batches = [SyntheticTask(tok, TaskConfig("retrieval", num_facts=nf,
                                             seed=11 + nf)).batch(n // 2)
               for nf in (4, 8)]
    reqs = make_requests(batches, pad=tok.PAD)[:n]
    for i, r in enumerate(reqs):
        r.max_new = max_new[i % len(max_new)]
    return reqs


KVCFG = KVCommConfig(ratio=0.5, selector="prior_only")


class TestSchedulerParity:
    """Acceptance: overlapped + continuously-batched outputs match the
    serial per-request reference token for token, across the transport /
    packing matrix."""

    @pytest.mark.parametrize("transport", [
        lambda: InMemoryTransport(),
        lambda: InMemoryTransport(packed=False),
        lambda: SerializedTransport("float32"),
        lambda: SerializedTransport("float32", packed=False),
        lambda: RemoteTransport("float32"),
        lambda: RemoteTransport("float32", packed=False),
    ], ids=["mem_packed", "mem_dense", "ser_packed", "ser_dense",
            "rem_packed", "rem_dense"])
    def test_tokens_match_serial(self, tiny_cfg, tok, transport):
        sess, _, _ = _session(tiny_cfg, tok, transport())
        reqs = _stream(tok)
        ser, _ = serve_serial(sess, reqs, KVCFG)
        sched = Scheduler(sess, KVCFG,
                          config=SchedulerConfig(capacity=3,
                                                 prefix_bucket=8,
                                                 query_bucket=4))
        got, stats = sched.run(reqs)
        assert [c.rid for c in got] == [c.rid for c in ser]
        for a, b in zip(ser, got):
            np.testing.assert_array_equal(a.tokens, b.tokens)
        # slots were actually reused mid-flight (continuous batching, not
        # batch-drain): more requests than capacity, one table
        assert len(reqs) > 3 and stats["occupancy"] > 0

    def test_zero_unselected_pos_mode(self, tiny_cfg, tok):
        """KVComm-S positions survive bucketing: the per-row shift is the
        REAL prefix on selected layers and 0 on unselected ones."""
        sess, _, _ = _session(tiny_cfg, tok, InMemoryTransport())
        kvcfg = KVCommConfig(ratio=0.5, selector="prior_only",
                             pos_mode="zero_unselected")
        reqs = _stream(tok, n=4, max_new=(3, 2))
        ser, _ = serve_serial(sess, reqs, kvcfg)
        got, _ = Scheduler(sess, kvcfg,
                           config=SchedulerConfig(capacity=2,
                                                  prefix_bucket=8,
                                                  query_bucket=4)).run(reqs)
        for a, b in zip(ser, got):
            np.testing.assert_array_equal(a.tokens, b.tokens)

    def test_padded_prefill_matches_natural(self, tiny_cfg, tiny_params):
        """The bucketing primitive in isolation: pad_prefix + prefix_lens
        masking answers exactly like the unpadded prefill."""
        cfg, params = tiny_cfg, tiny_params
        ctx = jax.random.randint(jax.random.PRNGKey(1), (1, 9), 4,
                                 cfg.vocab_size)
        kv, _ = core.sender_prefill(params, cfg, ctx)
        select = jnp.array([True, False, True, False])
        qry = jax.random.randint(jax.random.PRNGKey(2), (1, 5), 4,
                                 cfg.vocab_size)
        for build in (core.pack_shared, core.build_shared):
            shared = build(KVCFG, kv, select)
            ref = core.receiver_prefill(params, cfg, qry, shared, max_new=2)
            qpad = jnp.concatenate([qry, jnp.zeros((1, 3), jnp.int32)], 1)
            out = core.receiver_prefill(
                params, cfg, qpad, core.pad_prefix(shared, 16), max_new=2,
                prefix_lens=jnp.full((1,), 9, jnp.int32))
            np.testing.assert_allclose(np.asarray(out.logits[:, 4, :]),
                                       np.asarray(ref.logits[:, 4, :]),
                                       atol=2e-5)


class TestEosEarlyExit:
    """EOS-based early exit (ROADMAP PR-4 follow-up): a slot that emits the
    EOS token is retired and readmitted instead of decoding out its full
    budget — with token-for-token parity against the serial reference's
    stop-at-EOS semantics."""

    def _eos_for(self, sess, reqs):
        """Pick a token that the model really emits mid-stream (the tiny
        pair has no trained EOS; any recurring token works — determinism
        makes the choice stable)."""
        ser, _ = serve_serial(sess, reqs, KVCFG)
        counts = {}
        for c in ser:
            for t in c.tokens.tolist()[1:]:
                counts[t] = counts.get(t, 0) + 1
        assert counts, "streams too short to pick an EOS from"
        return max(counts, key=counts.get)

    def test_token_parity_with_serial_eos(self, tiny_cfg, tok):
        sess, _, _ = _session(tiny_cfg, tok, InMemoryTransport())
        reqs = _stream(tok, n=6, max_new=(8, 8, 8))
        eos = self._eos_for(sess, reqs)
        ser, _ = serve_serial(sess, reqs, KVCFG, eos_token=eos)
        got, _ = Scheduler(sess, KVCFG, config=SchedulerConfig(
            capacity=2, prefix_bucket=8, query_bucket=4,
            eos_token=eos)).run(reqs)
        assert [c.rid for c in got] == [c.rid for c in ser]
        for a, b in zip(ser, got):
            np.testing.assert_array_equal(a.tokens, b.tokens)
        # at least one stream really ended early (otherwise the test
        # pinned nothing)
        assert any(len(c.tokens) < r.max_new
                   for c, r in zip(ser, sorted(reqs, key=lambda r: r.rid)))

    def test_eos_frees_slots_for_readmission(self, tiny_cfg, tok):
        """The point of early exit: retiring at EOS drains the same stream
        in fewer slot iterations, because freed rows readmit pending
        requests instead of decoding dead tokens."""
        sess, _, _ = _session(tiny_cfg, tok, InMemoryTransport())
        reqs = _stream(tok, n=6, max_new=(8, 8, 8))
        eos = self._eos_for(sess, reqs)
        cfg_s = dict(capacity=2, prefix_bucket=8, query_bucket=4)
        _, full = Scheduler(sess, KVCFG,
                            config=SchedulerConfig(**cfg_s)).run(reqs)
        got, early = Scheduler(sess, KVCFG, config=SchedulerConfig(
            eos_token=eos, **cfg_s)).run(reqs)
        assert len(got) == len(reqs)          # everyone still completes
        assert early["iterations"] < full["iterations"]

    def test_first_token_eos_completes_immediately(self, tiny_cfg, tok):
        """A request whose FIRST (prefill) token is the EOS must complete
        with exactly [eos] — the lagged fetch-queue read retires it."""
        sess, _, _ = _session(tiny_cfg, tok, InMemoryTransport())
        reqs = _stream(tok, n=4, max_new=(6, 6))
        ser, _ = serve_serial(sess, reqs, KVCFG)
        eos = int(ser[0].tokens[0])           # rid 0's prefill token
        ser_e, _ = serve_serial(sess, reqs, KVCFG, eos_token=eos)
        got, _ = Scheduler(sess, KVCFG, config=SchedulerConfig(
            capacity=2, prefix_bucket=8, query_bucket=4,
            eos_token=eos)).run(reqs)
        assert got[0].tokens.tolist() == [eos]
        for a, b in zip(ser_e, got):
            np.testing.assert_array_equal(a.tokens, b.tokens)


class TestDeadSlotsInert:
    """Property: finished/empty slots never perturb live rows in the
    ragged step — whatever garbage their buffers, lengths, or tokens
    hold."""

    def _live_table(self, tiny_cfg, tok, cap=4):
        sess, cfg, params = _session(tiny_cfg, tok, InMemoryTransport())
        sched = Scheduler(sess, KVCFG,
                          config=SchedulerConfig(capacity=cap,
                                                 prefix_bucket=8,
                                                 query_bucket=4))
        reqs = _stream(tok, n=2, max_new=(6, 6))
        # admit two live rows by hand (run() would drain them)
        dst_prefix = ((max(len(r.context) for r in reqs) + 1 + 7) // 8) * 8
        query_max, budget = 4, 5
        z = sched._zero_shared(dst_prefix, cap)
        sched.meta = z.meta()
        table = tfm.init_cache(cfg, cap, query_max + budget, shared=z)
        table["len"] = jnp.full((cap,), dst_prefix, jnp.int32)
        state = {"table": table,
                 "prefix_lens": jnp.full((cap,), dst_prefix, jnp.int32),
                 "cur_tok": jnp.zeros((cap, 1), jnp.int32),
                 "active": jnp.zeros((cap,), bool),
                 "dst_prefix": dst_prefix, "query_max": query_max,
                 "budget": budget}
        for slot, r in enumerate(reqs):
            sched._admit(r, state, slot)
        return sess, sched, state, dst_prefix

    @pytest.mark.parametrize("seed", [0, 7, 1234, 65535])
    def test_garbage_dead_rows_do_not_change_live_rows(self, tiny_cfg, tok,
                                                       seed):
        sess, sched, state, dst_prefix = self._live_table(tiny_cfg, tok)
        rng = np.random.default_rng(seed)
        copy = lambda t: jax.tree.map(jnp.array, t)

        def garbage(t):
            """Randomize rows 2,3 of every batched buffer."""
            def g(x):
                if x.ndim < 2 or x.shape[1] != 4:
                    return x
                noise = jnp.asarray(
                    rng.standard_normal((x.shape[0], 2) + x.shape[2:])
                    .astype(np.asarray(x).dtype)
                    if jnp.issubdtype(x.dtype, jnp.floating) else
                    rng.integers(0, 2, (x.shape[0], 2) + x.shape[2:]))
                return x.at[:, 2:4].set(noise.astype(x.dtype))
            runs = jax.tree.map(g, t["runs"])
            ln = t["len"].at[2:].set(jnp.asarray(
                rng.integers(dst_prefix, dst_prefix + 8, (2,)), jnp.int32))
            return {"len": ln, "runs": runs}

        base = state["table"]
        tok_a, _, cache_a = sess.receiver.ragged_step(
            state["cur_tok"], copy(base), sched.meta,
            state["prefix_lens"], state["active"])
        dirty = garbage(copy(base))
        cur2 = state["cur_tok"].at[2:, 0].set(
            jnp.asarray(rng.integers(0, 20, (2,)), jnp.int32))
        pl2 = state["prefix_lens"].at[2:].set(jnp.asarray(
            rng.integers(1, dst_prefix, (2,)), jnp.int32))
        tok_b, _, cache_b = sess.receiver.ragged_step(
            cur2, dirty, sched.meta, pl2, state["active"])

        np.testing.assert_array_equal(np.asarray(tok_a[:2]),
                                      np.asarray(tok_b[:2]))

        def live_rows(t):
            return [np.asarray(x[:, :2]) for x in jax.tree.leaves(t["runs"])
                    if x.ndim >= 2 and x.shape[1] == 4]
        for a, b in zip(live_rows(cache_a), live_rows(cache_b)):
            np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(np.asarray(cache_a["len"][:2]),
                                      np.asarray(cache_b["len"][:2]))


class TestNoRetrace:
    def test_one_step_compile_per_selection_and_geometry(self, tiny_cfg,
                                                         tok):
        """The bucketing contract: ONE ragged-step compile per (frozen
        selection, table geometry) and one prefill/insert pair per bucket
        combination — never a compile per request."""
        sess, _, _ = _session(tiny_cfg, tok, InMemoryTransport())
        cfg_s = SchedulerConfig(capacity=5, prefix_bucket=8, query_bucket=4)
        reqs = _stream(tok, n=6, max_new=(5, 3, 1))
        base = dict(TRACE_COUNTS)
        Scheduler(sess, KVCFG, config=cfg_s).run(reqs)
        after_first = dict(TRACE_COUNTS)
        d_step = after_first.get("ragged_decode_step", 0) \
            - base.get("ragged_decode_step", 0)
        assert d_step == 1, f"expected one step compile, saw {d_step}"
        # a second, LARGER stream over the same buckets (and the same
        # decode budget, hence the same table geometry) compiles nothing
        more = _stream(tok, n=6, max_new=(4, 2, 5))
        for i, r in enumerate(more):
            r.rid += 100
        Scheduler(sess, KVCFG, config=cfg_s).run(reqs + more)
        for key in ("ragged_decode_step", "receiver_prefill",
                    "scheduler_insert"):
            assert TRACE_COUNTS.get(key, 0) == after_first.get(key, 0), \
                (key, dict(TRACE_COUNTS), after_first)

    def test_remote_admission_reuses_compiled_steps(self, tiny_cfg, tok):
        """Serving over a RemoteTransport must not cost a single extra
        trace: the decoded remote view is layout-identical to the
        in-memory one (same packed layers, same geometry), so admission
        through the framed codec reuses the very same compiled prefill /
        insert / ragged-step executables a warmed in-memory scheduler
        built."""
        cfg_s = SchedulerConfig(capacity=5, prefix_bucket=8, query_bucket=4)
        reqs = _stream(tok, n=6, max_new=(5, 3, 1))
        sess_mem, _, _ = _session(tiny_cfg, tok, InMemoryTransport())
        Scheduler(sess_mem, KVCFG, config=cfg_s).run(reqs)     # warm
        base = dict(TRACE_COUNTS)
        sess_rem, _, _ = _session(tiny_cfg, tok, RemoteTransport("float32"))
        got, _ = Scheduler(sess_rem, KVCFG, config=cfg_s).run(reqs)
        assert len(got) == len(reqs)
        for key in ("ragged_decode_step", "receiver_prefill",
                    "scheduler_insert"):
            assert TRACE_COUNTS.get(key, 0) == base.get(key, 0), \
                (key, dict(TRACE_COUNTS), base)


class TestTransportSync:
    def test_sync_default_still_stamps(self, tiny_cfg, tiny_params):
        cfg, params = tiny_cfg, tiny_params
        ctx = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 4,
                                 cfg.vocab_size)
        kv, _ = core.sender_prefill(params, cfg, ctx)
        select = jnp.array([True, False, True, False])
        for tr in (InMemoryTransport(), SerializedTransport("float16")):
            tr.send(cfg, KVCommConfig(), kv, select)
            assert tr.last.latency_s > 0.0

    def test_async_send_defers_stamp_to_flush(self, tiny_cfg, tiny_params):
        """The hot-path fix: sync=False returns without blocking, the
        record stays unstamped until flush_latency settles it."""
        cfg, params = tiny_cfg, tiny_params
        ctx = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 4,
                                 cfg.vocab_size)
        kv, _ = core.sender_prefill(params, cfg, ctx)
        select = jnp.array([True, False, True, False])
        for tr in (InMemoryTransport(sync=False),
                   SerializedTransport("float16", sync=False)):
            tr.send(cfg, KVCommConfig(), kv, select)
            assert tr.last.latency_s == 0.0      # deferred, not measured
            assert tr.flush_latency() == 1
            assert tr.last.latency_s > 0.0
            assert tr.flush_latency() == 0       # idempotent

    def test_synced_send_settles_pending_stamps(self, tiny_cfg,
                                                tiny_params):
        """A later synced send flushes the deferred log first (before its
        own timer starts), so records never stay unstamped behind it."""
        cfg, params = tiny_cfg, tiny_params
        ctx = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 4,
                                 cfg.vocab_size)
        kv, _ = core.sender_prefill(params, cfg, ctx)
        select = jnp.array([True, False, True, False])
        tr = InMemoryTransport()
        tr.send(cfg, KVCommConfig(), kv, select, sync=False)
        tr.send(cfg, KVCommConfig(), kv, select, sync=True)
        assert all(r.latency_s > 0.0 for r in tr.log)
        assert not tr._pending

    def test_per_call_override_beats_ctor_default(self, tiny_cfg,
                                                  tiny_params):
        cfg, params = tiny_cfg, tiny_params
        ctx = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 4,
                                 cfg.vocab_size)
        kv, _ = core.sender_prefill(params, cfg, ctx)
        select = jnp.array([True, False, True, False])
        tr = InMemoryTransport()                 # sync default
        tr.send(cfg, KVCommConfig(), kv, select, sync=False)
        assert tr.last.latency_s == 0.0
        tr.flush_latency()
        assert tr.last.latency_s > 0.0

    def test_poll_releases_drained_views(self, tiny_cfg, tiny_params):
        """The scheduler's per-iteration poll: once a deferred transfer
        has drained, its record is stamped and its view released — the
        pending log tracks in-flight transfers, not the stream length."""
        cfg, params = tiny_cfg, tiny_params
        ctx = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 4,
                                 cfg.vocab_size)
        kv, _ = core.sender_prefill(params, cfg, ctx)
        select = jnp.array([True, False, True, False])
        tr = InMemoryTransport(sync=False)
        shared = tr.send(cfg, KVCommConfig(), kv, select)
        jax.block_until_ready(shared)            # transfer definitely done
        assert tr.poll_latency() == 1
        assert not tr._pending and tr.last.latency_s > 0.0
        assert tr.poll_latency() == 0


class TestPagedAdmission:
    """The scheduler over a store-attached transport: admission gathers
    prefixes out of the content-addressed page pool (``_insert_paged_jit``)
    — token parity with the serial reference must hold and the compile
    counts must stay pinned (the page-count bucket IS the prefix bucket,
    so the store adds no new compile axis)."""

    def _paged(self, kind):
        from repro.store import PageStore
        store = PageStore(page_len=4)
        return {"mem": lambda: InMemoryTransport(store=store),
                "ser": lambda: SerializedTransport("float32", store=store),
                "rem": lambda: RemoteTransport("float32", store=store),
                }[kind]()

    @pytest.mark.parametrize("kind", ["mem", "ser", "rem"])
    def test_tokens_match_serial(self, tiny_cfg, tok, kind):
        sess_ref, _, _ = _session(tiny_cfg, tok, InMemoryTransport())
        reqs = _stream(tok)
        ser, _ = serve_serial(sess_ref, reqs, KVCFG)
        sess, _, _ = _session(tiny_cfg, tok, self._paged(kind))
        got, stats = Scheduler(
            sess, KVCFG, config=SchedulerConfig(capacity=3, prefix_bucket=8,
                                                query_bucket=4)).run(reqs)
        assert [c.rid for c in got] == [c.rid for c in ser]
        for a, b in zip(ser, got):
            np.testing.assert_array_equal(a.tokens, b.tokens)
        # the paged insert actually ran (multi-token requests only)
        n_paged = sum(1 for r in sess.transport.log if r.pages_total)
        assert n_paged == len(reqs)
        summary = sess.dedup_summary()
        assert summary["transfers"] == len(reqs)
        assert summary["pages_total"] > 0

    def test_trace_counts_stay_pinned_with_store(self, tiny_cfg, tok):
        """Acceptance: enabling the store keeps the bucketing contract —
        one paged-insert compile per (selection, prefix bucket, query
        bucket), zero new compiles for a second stream over the same
        buckets."""
        cfg_s = SchedulerConfig(capacity=5, prefix_bucket=8, query_bucket=4)
        reqs = _stream(tok, n=6, max_new=(5, 3, 1))
        sess, _, _ = _session(tiny_cfg, tok, self._paged("mem"))
        base = dict(TRACE_COUNTS)
        Scheduler(sess, KVCFG, config=cfg_s).run(reqs)
        after_first = dict(TRACE_COUNTS)
        d_ins = after_first.get("scheduler_insert_paged", 0) \
            - base.get("scheduler_insert_paged", 0)
        assert 1 <= d_ins <= 2, \
            f"paged insert must compile per bucket pair, saw {d_ins}"
        # the unpaged insert never traced — admissions routed via the store
        assert after_first.get("scheduler_insert", 0) \
            == base.get("scheduler_insert", 0)
        more = _stream(tok, n=6, max_new=(4, 2, 5))
        for r in more:
            r.rid += 100
        Scheduler(sess, KVCFG, config=cfg_s).run(reqs + more)
        for key in ("ragged_decode_step", "receiver_prefill",
                    "scheduler_insert_paged"):
            assert TRACE_COUNTS.get(key, 0) == after_first.get(key, 0), \
                (key, dict(TRACE_COUNTS), after_first)

    def test_repeat_contexts_dedup_across_admissions(self, tiny_cfg, tok):
        """Serving the SAME stream twice through one scheduler/session:
        every second-pass admission hits the pool (100% page hit rate on
        the repeats)."""
        sess, _, _ = _session(tiny_cfg, tok, self._paged("mem"))
        reqs = _stream(tok, n=3, max_new=(3, 2))
        sched = Scheduler(sess, KVCFG,
                          config=SchedulerConfig(capacity=2,
                                                 prefix_bucket=8,
                                                 query_bucket=4))
        ser, _ = serve_serial(_session(tiny_cfg, tok,
                                       InMemoryTransport())[0], reqs, KVCFG)
        first, _ = sched.run(reqs)
        n = len([r for r in sess.transport.log if r.pages_total])
        again = [dataclasses.replace(r, rid=r.rid + 10) for r in reqs]
        second, _ = sched.run(again)
        repeats = [r for r in sess.transport.log if r.pages_total][n:]
        assert repeats and all(r.hit_rate == 1.0 for r in repeats)
        assert all(r.n_bytes == 0 for r in repeats)
        for a, b in zip(ser, first):
            np.testing.assert_array_equal(a.tokens, b.tokens)
        for a, b in zip(ser, second):
            np.testing.assert_array_equal(a.tokens, b.tokens)


class TestPagedAsyncShare:
    """True ``sync=False`` for store-routed sends: the content hashing +
    pool ingest (the host-syncing stage) is deferred past the send, the
    same way latency stamping is — nothing blocks while an in-flight step
    is still decoding."""

    def _kv(self, tiny_cfg, tiny_params):
        ctx = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 4,
                                 tiny_cfg.vocab_size)
        kv, _ = core.sender_prefill(tiny_params, tiny_cfg, ctx)
        return kv, jnp.array([True, False, True, False])

    def _spy(self, monkeypatch):
        """Count PageStore.ingest calls AND jax.block_until_ready calls —
        ingest is the transitive host sync (hashing reads device bytes),
        block_until_ready the explicit one."""
        from repro.store import PageStore
        calls = {"ingest": 0, "block": 0}
        real_ingest = PageStore.ingest
        real_block = jax.block_until_ready

        def spy_ingest(store, *a, **k):
            calls["ingest"] += 1
            return real_ingest(store, *a, **k)

        def spy_block(x):
            calls["block"] += 1
            return real_block(x)

        monkeypatch.setattr(PageStore, "ingest", spy_ingest)
        monkeypatch.setattr(jax, "block_until_ready", spy_block)
        return calls

    @pytest.mark.parametrize("make", [
        lambda store: InMemoryTransport(store=store),
        lambda store: SerializedTransport("int8", store=store),
    ], ids=["mem_model_dtype", "ser_int8"])
    def test_async_send_defers_ingest(self, tiny_cfg, tiny_params,
                                      monkeypatch, make):
        from repro.store import PageStore
        kv, select = self._kv(tiny_cfg, tiny_params)
        calls = self._spy(monkeypatch)
        tr = make(PageStore(page_len=4))
        shared = tr.send(tiny_cfg, KVCommConfig(), kv, select, sync=False)
        # before the in-flight step retires: no hashing, no host block,
        # no table, unstamped zero-byte record
        assert calls == {"ingest": 0, "block": 0}
        assert tr._last_table is None
        assert tr.last.n_bytes == 0 and tr.last.pages_total == 0
        # flush settles the parked ingest and fills the record in place
        assert tr.flush_latency() >= 1
        assert calls["ingest"] == 1
        assert tr.last_table is not None
        assert tr.last.n_bytes > 0 and tr.last.pages_total > 0
        assert tr.last.pages_sent + tr.last.pages_hit \
            == tr.last.pages_total
        # the deferred receiver view is BIT-identical to a sync send's
        # pool-materialized view on a fresh store
        sync_tr = make(PageStore(page_len=4))
        ref = sync_tr.send(tiny_cfg, KVCommConfig(), kv, select, sync=True)
        for a, b in zip(jax.tree.leaves(shared.kv),
                        jax.tree.leaves(ref.kv)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_last_table_read_settles(self, tiny_cfg, tiny_params,
                                     monkeypatch):
        """First use of the table (the scheduler's paged insert) lands the
        ingest without an explicit flush."""
        from repro.store import PageStore
        kv, select = self._kv(tiny_cfg, tiny_params)
        calls = self._spy(monkeypatch)
        tr = InMemoryTransport(store=PageStore(page_len=4))
        tr.send(tiny_cfg, KVCommConfig(), kv, select, sync=False)
        assert calls["ingest"] == 0
        assert tr.last_table is not None      # property read settles
        assert calls["ingest"] == 1

    def test_sync_send_behind_async_preserves_order(self, tiny_cfg,
                                                    tiny_params,
                                                    monkeypatch):
        """A later synced paged send settles the parked ingest FIRST, so
        pool dedup and last_table keep send order."""
        from repro.store import PageStore
        kv, select = self._kv(tiny_cfg, tiny_params)
        calls = self._spy(monkeypatch)
        tr = InMemoryTransport(store=PageStore(page_len=4))
        tr.send(tiny_cfg, KVCommConfig(), kv, select, sync=False)
        tr.send(tiny_cfg, KVCommConfig(), kv, select, sync=True)
        assert calls["ingest"] == 2
        assert not tr._pending_ingest
        # the repeat send fully dedups against the first's pages
        assert tr.log[-1].pages_hit == tr.log[-1].pages_total > 0

    def test_states_force_sync_path(self, tiny_cfg, tiny_params):
        """SSM states ride alongside the pages with no deferred variant —
        a send carrying states ingests eagerly (correctness first)."""
        from repro.store import PageStore
        kv, select = self._kv(tiny_cfg, tiny_params)
        states = {"s": jnp.ones((2, 2, 4))}
        tr = InMemoryTransport(store=PageStore(page_len=4))
        tr.send(tiny_cfg, KVCommConfig(), kv, select, states=states,
                state_select=jnp.array([True, True]), sync=False)
        assert not tr._pending_ingest
        assert tr._last_table is not None


class TestSchedulerResilience:
    """Chaos + quarantine: the serving loop survives faulty and dead
    senders — recovering bit-identically under a RetryPolicy, degrading
    per-request (recorded on the Completion) when the transfer cannot be
    served, and never crashing the loop or leaking pins."""

    CFG_S = SchedulerConfig(capacity=3, prefix_bucket=8, query_bucket=4)

    def _remote(self, tiny_cfg, tok, schedule, *, store=None,
                resilience=None, policy=None):
        from repro.comm.resilience import FaultyChannel, RetryPolicy
        from repro.comm.remote import LoopbackChannel
        if policy is None:
            policy = RetryPolicy(max_attempts=3, backoff_s=0.0, jitter=0.0)
        ch = FaultyChannel(LoopbackChannel(), schedule)
        tr = RemoteTransport("float32", channel=ch, policy=policy,
                             store=store)
        sess, _, _ = _session(tiny_cfg, tok, tr)
        sess.resilience = resilience
        return sess, ch

    @pytest.mark.parametrize("seed", [0, 1])
    def test_chaos_recovery_token_identical(self, tiny_cfg, tok, seed):
        """Seeded faults at admission frame boundaries (spaced so the
        policy always has a clean retry window): the chaos run's
        completions are bit-identical to the no-fault run, nothing
        degrades, and the burned attempts land in the transfer log.

        Spacing: unpaged shares stream by default — a clean share is 4
        frame writes (begin, k-chunk, v-chunk, end) and any fault ends
        the attempt after exactly its own write (every stream frame is
        echoed and checked before the next encode).  A fault on each
        share's FIRST write therefore costs that share 1 + 4 writes, so
        ops 0 / 5 / 10 hit the first write of shares 1-3 and every retry
        replays under a fresh sid on a healed channel."""
        import random
        from repro.comm.resilience import Fault, FaultSchedule
        rng = random.Random(seed)
        kinds = [rng.choice(["drop", "truncate", "corrupt", "disconnect"])
                 for _ in range(3)]
        schedule = FaultSchedule(
            [Fault(op, k, frac=rng.uniform(0.2, 0.8))
             for op, k in zip((0, 5, 10), kinds)])
        reqs = _stream(tok)
        clean_sess, _ = self._remote(tiny_cfg, tok, FaultSchedule())
        ref, _ = Scheduler(clean_sess, KVCFG, config=self.CFG_S).run(reqs)
        sess, ch = self._remote(tiny_cfg, tok, schedule)
        got, _ = Scheduler(sess, KVCFG, config=self.CFG_S).run(reqs)
        assert [c.rid for c in got] == [c.rid for c in ref]
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a.tokens, b.tokens)
        assert all(c.degradation is None for c in got)
        assert len(schedule) == 0                   # every fault fired
        retried = [r for r in sess.transport.log if r.attempts > 1]
        assert len(retried) == 3
        assert all(r.attempts == 2 for r in retried)

    def test_chaos_paged_no_leaked_pins(self, tiny_cfg, tok):
        """The paged admission path under faults: token parity with the
        clean paged run AND zero pinned pool bytes once the last table is
        released."""
        from repro.comm.resilience import Fault, FaultSchedule
        from repro.store import PageStore
        reqs = _stream(tok, n=4, max_new=(3, 2))
        clean_sess, _ = self._remote(tiny_cfg, tok, FaultSchedule(),
                                     store=PageStore(page_len=4))
        ref, _ = Scheduler(clean_sess, KVCFG, config=self.CFG_S).run(reqs)
        store = PageStore(page_len=4)
        # share = 3 writes; faults placed so no exchange eats two faults
        schedule = FaultSchedule([Fault(0, "truncate", frac=0.5),
                                  Fault(8, "disconnect")])
        sess, ch = self._remote(tiny_cfg, tok, schedule, store=store)
        got, _ = Scheduler(sess, KVCFG, config=self.CFG_S).run(reqs)
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a.tokens, b.tokens)
        assert all(c.degradation is None for c in got)
        assert len(schedule) == 0
        sess.transport.release_table()
        assert store.stats().pinned_bytes == 0

    def test_dead_sender_degrades_every_request(self, tiny_cfg, tok):
        """A permanently dead sender with a baseline-only ladder: the loop
        finishes, every completion is served text-only with its
        DegradationEvent attached, and the scheduler matches the serial
        reference (which degrades identically)."""
        from repro.comm.resilience import Resilience
        reqs = _stream(tok, n=4, max_new=(3, 2))
        ser_sess, _ = self._remote(
            tiny_cfg, tok, None, resilience=Resilience(),
            policy=RetryPolicy(max_attempts=2, backoff_s=0.0, jitter=0.0))
        ser_sess.transport.channel = _AlwaysDown()
        ser, _ = serve_serial(ser_sess, reqs, KVCFG)
        assert all(c.degradation is not None
                   and c.degradation.stage == "baseline" for c in ser)
        sess, _ = self._remote(
            tiny_cfg, tok, None, resilience=Resilience(),
            policy=RetryPolicy(max_attempts=2, backoff_s=0.0, jitter=0.0))
        sess.transport.channel = _AlwaysDown()
        got, stats = Scheduler(sess, KVCFG, config=self.CFG_S).run(reqs)
        assert [c.rid for c in got] == [c.rid for c in ser]
        for a, b in zip(ser, got):
            np.testing.assert_array_equal(a.tokens, b.tokens)
        for c in got:
            assert c.degradation is not None
            assert c.degradation.stage == "baseline"
            assert c.degradation.rid == c.rid
        # the degraded transfers are zero-byte rows in the log
        assert all(r.n_bytes == 0 for r in sess.transport.log)

    def test_quarantine_without_ladder_keeps_loop_alive(self, tiny_cfg,
                                                        tok):
        """No session ladder at all: the scheduler itself catches the
        exhausted share, quarantines the admission to text-only, and keeps
        serving — token-identical to the ladder path."""
        from repro.comm.resilience import Resilience
        reqs = _stream(tok, n=4, max_new=(3, 2))
        ref_sess, _ = self._remote(
            tiny_cfg, tok, None, resilience=Resilience(),
            policy=RetryPolicy(max_attempts=2, backoff_s=0.0, jitter=0.0))
        ref_sess.transport.channel = _AlwaysDown()
        ref, _ = Scheduler(ref_sess, KVCFG, config=self.CFG_S).run(reqs)
        sess, _ = self._remote(
            tiny_cfg, tok, None, resilience=None,
            policy=RetryPolicy(max_attempts=2, backoff_s=0.0, jitter=0.0))
        sess.transport.channel = _AlwaysDown()
        got, _ = Scheduler(sess, KVCFG, config=self.CFG_S).run(reqs)
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a.tokens, b.tokens)
        for c in got:
            assert c.degradation is not None
            assert c.degradation.stage == "baseline"
        assert all(r.n_bytes == 0 for r in sess.transport.log)

    def test_degraded_admission_adds_no_new_traces(self, tiny_cfg, tok):
        """The baseline rung reuses the healthy path's compiled prefill /
        insert / ragged step (prefix_lens=0 masks the zero prefix at
        runtime — no new shapes, no new compiles)."""
        from repro.comm.resilience import Resilience
        reqs = _stream(tok, n=4, max_new=(3, 2))
        warm_sess, _ = self._remote(tiny_cfg, tok, None)
        Scheduler(warm_sess, KVCFG, config=self.CFG_S).run(reqs)
        base = dict(TRACE_COUNTS)
        sess, _ = self._remote(
            tiny_cfg, tok, None, resilience=Resilience(),
            policy=RetryPolicy(max_attempts=2, backoff_s=0.0, jitter=0.0))
        sess.transport.channel = _AlwaysDown()
        got, _ = Scheduler(sess, KVCFG, config=self.CFG_S).run(reqs)
        assert all(c.degradation is not None for c in got)
        for key in ("ragged_decode_step", "receiver_prefill",
                    "scheduler_insert"):
            assert TRACE_COUNTS.get(key, 0) == base.get(key, 0), \
                (key, dict(TRACE_COUNTS), base)


class _AlwaysDown:
    """A channel whose peer is gone and stays gone."""

    def write(self, data):
        from repro.comm.remote import ChannelClosedError
        raise ChannelClosedError("peer is gone")

    def read(self, n):
        return b""

    def close(self):
        pass

    def reset(self):
        pass
