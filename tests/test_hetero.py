"""Heterogeneous model pairs: per-side selection + LayerMap policies.

The conformance matrix behind the tentpole: every mapping policy x
{packed, dense} x {InMemory, Serialized} transport, on a same-depth pair
(where the identity map must be bit-exact with the classic kvcomm path)
and on depth-mismatched pairs in both directions (6->10 shallower sender,
10->6 deeper sender), asserting finite logits, receiver-side cache shapes,
and transport-measured bytes equal to the analytic ``kv_wire_bytes``
prediction at the mapped pair count P (NOT the sender's M — policies may
drop layers, and only receiver-consumable KV crosses the wire).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.comm import (Agent, CommSession, InMemoryTransport,
                        SerializedTransport)
from repro.core.layermap import (LAYER_MAPS, DepthProportional,
                                 IdentityTruncate, LayerAssignment, LayerMap,
                                 ScoreGreedy, get_layer_map,
                                 register_layer_map)
from repro.core.types import KVCommConfig
from repro.data.synthetic import SyntheticTask, TaskConfig
from repro.models import transformer as tfm

POLICIES = ["identity", "depth_proportional", "score_greedy"]

TRANSPORTS = {
    "mem_packed": lambda: InMemoryTransport(),
    "mem_dense": lambda: InMemoryTransport(packed=False),
    "ser_packed": lambda: SerializedTransport("float16"),
    "ser_dense": lambda: SerializedTransport("float16", packed=False),
}

# wire itemsize per transport: InMemory moves the model dtype (float32
# here), Serialized casts to fp16
ITEMSIZE = {"mem_packed": 4, "mem_dense": 4, "ser_packed": 2,
            "ser_dense": 2}


def _cfg(tok, L):
    from repro.configs.registry import get_config
    return dataclasses.replace(
        get_config("llama3.2-3b-pair"),
        num_layers=L, d_model=64, d_ff=128, num_heads=4, num_kv_heads=2,
        head_dim=16, vocab_size=tok.vocab_size, dtype="float32",
        remat=False, tie_embeddings=False)


@pytest.fixture(scope="module")
def models(tok):
    """Params for 6- and 10-layer tiny models (shared across the matrix)."""
    cfgs = {L: _cfg(tok, L) for L in (6, 10)}
    params = {L: tfm.init_params(cfgs[L], jax.random.PRNGKey(L))
              for L in cfgs}
    return cfgs, params


@pytest.fixture(scope="module")
def batch(tok):
    return SyntheticTask(tok, TaskConfig("retrieval", num_facts=4,
                                         seed=11)).batch(2)


def _session(models, tok, L_s, L_r, transport=None):
    cfgs, params = models
    return CommSession(Agent("s", cfgs[L_s], params[L_s], tok),
                       Agent("r", cfgs[L_r], params[L_r], tok), transport)


KVCFG = KVCommConfig(ratio=0.5, selector="prior_only")


# ---------------------------------------------------------------------------
# policy unit tests (no model involved)
# ---------------------------------------------------------------------------
class TestLayerMapPolicies:
    def test_registry_has_baselines(self):
        assert set(POLICIES) <= set(LAYER_MAPS)
        assert isinstance(get_layer_map("identity"), IdentityTruncate)
        with pytest.raises(ValueError, match="unknown layer map"):
            get_layer_map("wormhole")

    def test_identity_truncates_deep_sender(self):
        a = IdentityTruncate().assign([0, 3, 5, 8], 10, 6)
        assert a.src == a.dst == (0, 3, 5)    # 8 >= L_dst: dropped
        assert a.num_pairs == 3

    def test_identity_same_depth_is_identity(self):
        a = IdentityTruncate().assign([1, 4], 6, 6)
        assert a.is_identity and a.src == (1, 4)

    def test_depth_proportional_endpoints_and_monotonicity(self):
        a = DepthProportional().assign(list(range(6)), 6, 10)
        assert a.dst[0] == 0 and a.dst[-1] == 9   # endpoints pinned
        assert all(x < y for x, y in zip(a.dst, a.dst[1:]))
        assert a.src == tuple(range(6))           # nothing dropped, 6 <= 10

    def test_depth_proportional_same_depth_is_identity(self):
        a = DepthProportional().assign([0, 2, 5], 6, 6)
        assert a.is_identity

    def test_depth_proportional_collisions_keep_shallowest(self):
        # 10 -> 4: scale 1/3; layers 0,1,2 all round to slot 0 or 1
        a = DepthProportional().assign(list(range(10)), 10, 4)
        assert len(a.dst) == len(set(a.dst)) == a.num_pairs <= 4
        assert a.dst[0] == 0 and a.src[0] == 0

    def test_score_greedy_prefers_high_scoring_slots(self):
        dst_scores = np.zeros(10)
        dst_scores[[2, 5, 7]] = 1.0
        a = ScoreGreedy().assign([0, 1, 2], 6, 10,
                                 dst_scores=dst_scores)
        assert a.dst == (2, 5, 7)
        assert a.src == (0, 1, 2)    # depth order preserved on both sides

    def test_score_greedy_drops_lowest_scoring_sender_layers(self):
        src_scores = np.array([0.9, 0.1, 0.8, 0.2, 0.7, 0.3])
        a = ScoreGreedy().assign(list(range(6)), 6, 3,
                                 src_scores=src_scores)
        assert a.src == (0, 2, 4)    # the three best, back in depth order
        assert a.num_pairs == 3

    def test_assignment_invariants_enforced(self):
        with pytest.raises(AssertionError):
            LayerAssignment(src=(0, 1), dst=(3, 2), num_src_layers=6,
                            num_dst_layers=6)   # dst not ascending
        with pytest.raises(AssertionError):
            LayerAssignment(src=(0,), dst=(9,), num_src_layers=6,
                            num_dst_layers=6)   # dst out of range
        with pytest.raises(AssertionError):
            LayerAssignment(src=(0, 1), dst=(2,), num_src_layers=6,
                            num_dst_layers=6)   # unpaired

    def test_custom_policy_registration(self, models, tok, batch):
        """README's extension point: a registered policy is reachable by
        name through session.run('hetero_kvcomm', layer_map=...)."""
        class FirstOnly(LayerMap):
            name = "first_only"

            def assign(self, src_layers, num_src_layers, num_dst_layers,
                       src_scores=None, dst_scores=None):
                i = min(src_layers)
                return LayerAssignment(
                    src=(i,), dst=(0,), num_src_layers=num_src_layers,
                    num_dst_layers=num_dst_layers)

        register_layer_map(FirstOnly())
        try:
            sess = _session(models, tok, 6, 10)
            res = sess.run("hetero_kvcomm", batch, kvcfg=KVCFG,
                           layer_map="first_only")
            assert res.extras["M"] == 1
            assert res.extras["dst_layers"] == (0,)
        finally:
            del LAYER_MAPS["first_only"]


# ---------------------------------------------------------------------------
# the conformance matrix
# ---------------------------------------------------------------------------
class TestSameDepthBitExact:
    """(a) same-L + identity map == today's kvcomm path, bit for bit."""

    @pytest.mark.parametrize("transport", sorted(TRANSPORTS))
    def test_shared_views_identical(self, models, tok, batch, transport):
        sess_a = _session(models, tok, 6, 6, TRANSPORTS[transport]())
        sess_b = _session(models, tok, 6, 6, TRANSPORTS[transport]())
        shared_a, select = sess_a.share(batch["context"], KVCFG)
        shared_b, asg = sess_b.share_mapped(batch["context"], KVCFG,
                                            policy="identity")
        assert asg.is_identity
        assert sess_a.transport.last.n_bytes == sess_b.transport.last.n_bytes
        assert sess_a.transport.last.layers == sess_b.transport.last.layers
        np.testing.assert_array_equal(np.asarray(shared_a.select),
                                      np.asarray(shared_b.select))
        if shared_a.is_packed:
            assert shared_a.layers == shared_b.layers
            for p in ("k", "v"):
                np.testing.assert_array_equal(
                    np.asarray(shared_a.packed_kv[p]),
                    np.asarray(shared_b.packed_kv[p]))
        else:
            # dense views: the classic InMemory hand-over is zero-copy
            # (unselected layers keep the sender buffers, masked out by
            # ``select``); the mapped one scatters zeros there. What the
            # receiver consumes — the selected layers — must be identical.
            idx = np.nonzero(np.asarray(select))[0]
            for p in ("k", "v"):
                np.testing.assert_array_equal(
                    np.asarray(shared_a.kv[p])[idx],
                    np.asarray(shared_b.kv[p])[idx])

    def test_run_preds_and_bytes_identical(self, models, tok, batch):
        a = _session(models, tok, 6, 6).run("kvcomm", batch, kvcfg=KVCFG)
        b = _session(models, tok, 6, 6).run("hetero_kvcomm", batch,
                                            kvcfg=KVCFG,
                                            layer_map="identity")
        np.testing.assert_array_equal(a.preds, b.preds)
        assert a.wire_bytes == b.wire_bytes
        assert a.extras["M"] == b.extras["M"]

    @pytest.mark.parametrize("policy", POLICIES)
    def test_every_policy_is_identity_at_same_depth_prior(self, policy):
        """With per-side priors equal (same depth), no policy may relocate
        a layer: all three baselines degenerate to the identity map."""
        src = core.selected_layer_ids(
            core.select_layers(None, 6, KVCFG))
        a = get_layer_map(policy).assign(src, 6, 6)
        assert a.is_identity and a.src == src


class TestCrossDepthMatrix:
    """(b) different-L: finite logits, correct receiver cache shapes, and
    measured bytes == analytic kv_wire_bytes at the mapped pair count."""

    @pytest.mark.parametrize("transport", sorted(TRANSPORTS))
    @pytest.mark.parametrize("policy", POLICIES)
    def test_shallow_sender_deep_receiver(self, models, tok, batch,
                                          policy, transport):
        self._matrix_case(models, tok, batch, 6, 10, policy, transport)

    @pytest.mark.parametrize("transport", sorted(TRANSPORTS))
    @pytest.mark.parametrize("policy", POLICIES)
    def test_deep_sender_shallow_receiver(self, models, tok, batch,
                                          policy, transport):
        """The truncating direction: the sender selects M = 5 of 10 layers
        but at most 6 receiver slots exist — policies drop layers and the
        wire must carry only the surviving P pairs."""
        self._matrix_case(models, tok, batch, 10, 6, policy, transport)

    def _matrix_case(self, models, tok, batch, L_s, L_r, policy,
                     transport):
        cfgs, _ = models
        sess = _session(models, tok, L_s, L_r, TRANSPORTS[transport]())
        assert sess.is_hetero
        shared, asg = sess.share_mapped(batch["context"], KVCFG,
                                        policy=policy)
        rcfg = cfgs[L_r]
        P = asg.num_pairs
        assert 1 <= P <= min(rcfg.attn_layer_count,
                             cfgs[L_s].attn_layer_count)

        # --- transport-measured bytes == analytic prediction at P -------
        rec = sess.transport.last
        Sc = batch["context"].shape[1] + 1          # export_kv adds BOS
        assert rec.layers == P
        assert rec.context_len == Sc
        assert rec.n_bytes == core.kv_wire_bytes(
            rcfg, batch["context"].shape[0], Sc, P, ITEMSIZE[transport])

        # --- receiver-side view is keyed by receiver slots ---------------
        np.testing.assert_array_equal(np.asarray(shared.select),
                                      asg.dst_mask())
        if shared.is_packed:
            assert shared.layers == asg.dst
            assert shared.src_layers == asg.src
        else:
            assert shared.kv["k"].shape[0] == rcfg.attn_layer_count

        # --- finite logits + correct cache geometry ----------------------
        Sq, max_new = batch["query"].shape[1], 2
        out = sess.receiver.prefill(batch["query"], shared,
                                    max_new=max_new)
        assert np.isfinite(np.asarray(out.logits)).all()
        self._check_cache_shapes(rcfg, out.cache, shared, asg,
                                 B=batch["query"].shape[0],
                                 S_new=Sq + max_new)

    @staticmethod
    def _check_cache_shapes(rcfg, cache, shared, asg, B, S_new):
        Hkv, Dh = rcfg.num_kv_heads, rcfg.resolved_head_dim
        Sc = shared.prefix_len
        attn_i = 0
        for spec, run in zip(rcfg.layer_plan(), cache["runs"]):
            n = spec.count
            in_run = [j for j in asg.dst if attn_i <= j < attn_i + n]
            if shared.is_packed:
                # selected stack carries the prefix, unselected is
                # prefix-free — buffers scale with P, not L
                assert run["sel"]["k"].shape == (
                    len(in_run), B, Sc + S_new, Hkv, Dh)
                assert run["unsel"]["k"].shape == (
                    n - len(in_run), B, S_new, Hkv, Dh)
                assert bool(run["sel"]["ctx_valid"].all())
                assert not bool(run["unsel"]["ctx_valid"].any())
            else:
                assert run["k"].shape == (n, B, Sc + S_new, Hkv, Dh)
                np.testing.assert_array_equal(
                    np.asarray(run["ctx_valid"]),
                    np.asarray(shared.select)[attn_i:attn_i + n])
            attn_i += n

    def test_byte_accounting_when_sender_M_exceeds_pairs(self, models,
                                                         tok, batch):
        """10 -> 6 identity: the sender selects 5 layers, only those below
        depth 6 survive — measured bytes must track P, not M_sender."""
        cfgs, _ = models
        sess = _session(models, tok, 10, 6)
        src_select = sess.side_selection("sender", KVCFG)
        M_sender = int(np.asarray(src_select).sum())
        shared, asg = sess.share_mapped(batch["context"], KVCFG,
                                        policy="identity")
        assert asg.num_pairs < M_sender    # identity truncated something
        rec = sess.transport.last
        assert rec.layers == asg.num_pairs
        assert rec.n_bytes == core.kv_wire_bytes(
            cfgs[6], batch["context"].shape[0],
            batch["context"].shape[1] + 1, asg.num_pairs, 4)


class TestHeteroGeneration:
    def test_stream_matches_generate_through_mapped_prefix(self, models,
                                                           tok, batch):
        """The packed fast path (jitted donated decode) must digest a
        mapped SharedKV exactly like compiled generation does."""
        sess = _session(models, tok, 6, 10)
        shared, _ = sess.share_mapped(batch["context"], KVCFG,
                                      policy="depth_proportional")
        toks = sess.generate(batch["query"], shared, max_new=4)
        streamed = np.stack(list(sess.stream(batch["query"], shared,
                                             max_new=4)), axis=1)
        np.testing.assert_array_equal(toks, streamed)

    def test_packed_dense_logit_parity_hetero(self, models, tok, batch):
        """Mapped packed view == mapped dense view on the receiver."""
        sess_p = _session(models, tok, 6, 10, InMemoryTransport())
        sess_d = _session(models, tok, 6, 10,
                          InMemoryTransport(packed=False))
        sh_p, _ = sess_p.share_mapped(batch["context"], KVCFG,
                                      policy="score_greedy")
        sh_d, _ = sess_d.share_mapped(batch["context"], KVCFG,
                                      policy="score_greedy")
        a = sess_p.receiver.prefill(batch["query"], sh_p, max_new=0)
        b = sess_d.receiver.prefill(batch["query"], sh_d, max_new=0)
        np.testing.assert_allclose(np.asarray(a.logits),
                                   np.asarray(b.logits), atol=2e-5)


class TestHeteroSession:
    def test_is_hetero_flag(self, models, tok):
        assert _session(models, tok, 6, 10).is_hetero
        assert not _session(models, tok, 6, 6).is_hetero

    def test_is_hetero_sees_ssm_depth_mismatch(self, tok):
        """Equal attention depth with mismatched SSM depth must still
        count as heterogeneous (state sharing is positional): the classic
        path would ship a wrong-depth states stack; share_mapped drops
        states instead."""
        from repro.configs.registry import get_config
        base = dataclasses.replace(get_config("zamba2-2.7b").reduced(),
                                   dtype="float32",
                                   vocab_size=tok.vocab_size)
        # same group count (= attn count) but more mamba layers per group
        scfg = dataclasses.replace(base, num_layers=2, hybrid_attn_every=2)
        rcfg = dataclasses.replace(base, num_layers=3, hybrid_attn_every=3)
        assert scfg.attn_layer_count == rcfg.attn_layer_count == 1
        sp = tfm.init_params(scfg, jax.random.PRNGKey(0))
        rp = tfm.init_params(rcfg, jax.random.PRNGKey(1))
        sess = CommSession(Agent("s", scfg, sp, tok),
                           Agent("r", rcfg, rp, tok))
        assert sess.is_hetero
        rng = np.random.default_rng(0)
        ctx = rng.integers(4, scfg.vocab_size, (2, 6)).astype(np.int32)
        qry = rng.integers(4, scfg.vocab_size, (2, 4)).astype(np.int32)
        with pytest.raises(AssertionError, match="share_mapped"):
            sess.share(ctx, KVCFG)
        shared, _ = sess.share_mapped(ctx, KVCFG, policy="identity")
        assert shared.states is None      # positional states dropped
        out = sess.receiver.prefill(qry, shared, max_new=0)
        assert np.isfinite(np.asarray(out.logits)).all()

    def test_nld_flops_priced_per_side(self, models, tok, batch):
        """nld/cipher run fine across depths (text crosses, not KV), but
        the sender half of the FLOP bill must use the sender's depth."""
        from repro.serving import costs
        cfgs, _ = models
        res = _session(models, tok, 6, 10).run("nld", batch, nld_tokens=4)
        C, Q = batch["context"].shape[1], batch["query"].shape[1]
        assert res.flops == costs.flops_nld(cfgs[10], C, Q, 1, 4,
                                            sender_cfg=cfgs[6])
        assert res.flops < costs.flops_nld(cfgs[10], C, Q, 1, 4)

    def test_classic_share_refuses_hetero(self, models, tok, batch):
        sess = _session(models, tok, 6, 10)
        with pytest.raises(AssertionError, match="share_mapped"):
            sess.share(batch["context"], KVCFG)
        with pytest.raises(AssertionError, match="calibrate_side"):
            sess.calibrate(batch["context"][:1], batch["query"][:1])

    @pytest.mark.parametrize("method", ["ac_replace", "ac_mean", "ac_sum"])
    def test_ac_baselines_refuse_hetero(self, models, tok, batch, method):
        """Hidden-state injection is same-index by construction: it must
        refuse a depth-mismatched session instead of crashing (6->10) or
        silently misaligning (10->6)."""
        for L_s, L_r in ((6, 10), (10, 6)):
            sess = _session(models, tok, L_s, L_r)
            with pytest.raises(AssertionError, match="equal depths"):
                sess.run(method, batch)

    def test_multi_sender_mailbox_refuses_depth_mismatch(self, models,
                                                         tok, batch):
        """Mailbox composition indexes the attached sender's KV with
        receiver-keyed selections — a depth-mismatched sender must be
        rejected, not silently gather-clamped (mapped multi-sender is a
        ROADMAP follow-up)."""
        sess = _session(models, tok, 6, 10)
        h = sess.attach_sender(sess.sender, name="extra")
        with pytest.raises(AssertionError, match="depth"):
            h.send(batch["context"], KVCFG)

    def test_geometry_mismatch_rejected(self, models, tok):
        cfgs, params = models
        bad = dataclasses.replace(cfgs[10], num_kv_heads=1)
        bad_params = tfm.init_params(bad, jax.random.PRNGKey(9))
        with pytest.raises(AssertionError, match="KV geometry"):
            CommSession(Agent("s", cfgs[6], params[6], tok),
                        Agent("r", bad, bad_params, tok))

    def test_per_side_calibration_shapes_and_cache(self, models, tok,
                                                   batch):
        sess = _session(models, tok, 6, 10)
        ctx, qry = batch["context"][:1], batch["query"][:1]
        s = sess.calibrate_side("sender", ctx, qry, key="t")
        r = sess.calibrate_side("receiver", ctx, qry, key="t")
        assert s.shape == (6,) and r.shape == (10,)
        assert sess.calibrate_side("sender", ctx, qry, key="t") is s
        sel_s = sess.side_selection("sender", KVCFG, key="t")
        sel_r = sess.side_selection("receiver", KVCFG, key="t")
        assert sel_s.shape == (6,) and sel_r.shape == (10,)
        assert sess.side_selection("sender", KVCFG, key="t") is sel_s

    def test_share_mapped_uses_cached_side_scores(self, models, tok,
                                                  batch):
        """Scores calibrated under a task key feed the mapping without
        being passed explicitly (the frozen-selection discipline)."""
        sess = _session(models, tok, 6, 10)
        ctx, qry = batch["context"][:1], batch["query"][:1]
        sess.calibrate_side("sender", ctx, qry, key="t")
        sess.calibrate_side("receiver", ctx, qry, key="t")
        kvcfg = KVCommConfig(ratio=0.5, alpha=1.0, selector="kvcomm")
        shared, asg = sess.share_mapped(batch["context"], kvcfg,
                                        policy="score_greedy", key="t")
        expect_src = core.selected_layer_ids(
            sess.side_selection("sender", kvcfg, key="t"))
        assert set(asg.src) <= set(expect_src)
        assert shared.is_packed and shared.layers == asg.dst
