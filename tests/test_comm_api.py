"""The repro.comm stack: registry coverage, transport byte accounting,
multi-sender composition, and old->new facade parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.comm import (METHODS, Agent, CommSession, InMemoryTransport,
                        SerializedTransport)
from repro.core.types import KVCommConfig, SharedKV
from repro.data.synthetic import SyntheticTask, TaskConfig
from repro.models import transformer as tfm
from repro.serving.engine import CommEngine

# every method string the legacy string-dispatch engine accepted
LEGACY_METHODS = ["baseline", "skyline", "kvcomm", "random", "contiguous",
                  "prior_only", "full_kv", "nld", "cipher", "ac_replace",
                  "ac_mean", "ac_sum"]

# methods that move no payload at all (the no-communication anchors)
SILENT_METHODS = {"baseline", "skyline"}


@pytest.fixture(scope="module")
def pair(tok):
    import conftest  # noqa: F401
    from repro.configs.registry import get_config
    cfg = dataclasses.replace(
        get_config("llama3.2-3b-pair"),
        num_layers=4, d_model=64, d_ff=128, num_heads=4, num_kv_heads=2,
        head_dim=16, vocab_size=tok.vocab_size, dtype="float32",
        remat=False, tie_embeddings=False)
    sender = tfm.init_params(cfg, jax.random.PRNGKey(0))
    receiver = tfm.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, sender, receiver


def _session(cfg, sender, receiver, tok, transport=None):
    return CommSession(Agent("s", cfg, sender, tok),
                       Agent("r", cfg, receiver, tok), transport)


@pytest.fixture(scope="module")
def batch(tok):
    task = SyntheticTask(tok, TaskConfig("retrieval", num_facts=4, seed=3))
    return task.batch(4)


class TestRegistry:
    def test_covers_every_legacy_method(self):
        missing = [m for m in LEGACY_METHODS if m not in METHODS]
        assert not missing, f"registry lacks legacy methods: {missing}"

    def test_unknown_method_raises(self, pair, batch, tok):
        cfg, s, r = pair
        with pytest.raises(ValueError, match="unknown method"):
            _session(cfg, s, r, tok).run("quantum_telepathy", batch)

class TestMethodContract:
    """Registry conformance: EVERY registered method (including ones
    registered after this test was written) must run end-to-end on the
    tiny pair through ``CommSession.run`` and honour the ``MethodResult``
    contract — latency stamped, accuracy a probability, and wire bytes
    that match the analytic prediction for whatever its TransferRecord
    claims was moved (zero for the no-communication anchors)."""

    NLD_TOKENS = 4

    def _expected_bytes(self, cfg, rec, batch):
        B = batch["context"].shape[0]
        if rec.kind == "kv":
            # InMemoryTransport moves the model dtype (float32 here)
            return core.kv_wire_bytes(cfg, B, rec.context_len, rec.layers,
                                      itemsize=4)
        if rec.kind == "text":
            # context_len holds the token count; 2 B/token for NLD ids,
            # d_model x 2 B for cipher soft tokens (pinned exactly below)
            per_tok = rec.n_bytes // max(rec.context_len, 1)
            assert per_tok in (2, cfg.d_model * 2)
            return rec.context_len * per_tok
        if rec.kind == "hidden":
            return B * cfg.d_model * 2
        raise AssertionError(f"unknown transfer kind {rec.kind!r}")

    @pytest.mark.parametrize("method", sorted(METHODS))
    def test_contract(self, pair, batch, tok, method):
        cfg, s, r = pair
        sess = _session(cfg, s, r, tok)
        res = sess.run(method, batch,
                       kvcfg=KVCommConfig(ratio=0.5, selector="prior_only"),
                       nld_tokens=self.NLD_TOKENS)
        B = batch["context"].shape[0]
        assert res.preds.shape == (B,)
        assert 0.0 <= res.accuracy <= 1.0
        assert res.latency_s > 0
        assert res.flops > 0
        if method in SILENT_METHODS:
            assert res.wire_bytes == 0
            assert res.transfer is None
            assert len(sess.transport.log) == 0
        else:
            assert res.transfer is not None
            assert res.wire_bytes == res.transfer.n_bytes > 0
            assert res.wire_bytes == self._expected_bytes(
                cfg, res.transfer, batch)

    def test_cipher_accounts_embedding_bytes(self, pair, batch, tok):
        """cipher ships d_model-wide soft tokens, not 2-byte ids — its
        text record carries the fatter per-token cost."""
        cfg, s, r = pair
        sess = _session(cfg, s, r, tok)
        res = sess.run("cipher", batch, nld_tokens=self.NLD_TOKENS)
        B = batch["context"].shape[0]
        assert res.wire_bytes == self.NLD_TOKENS * B * cfg.d_model * 2


class TestSerializedTransport:
    # three shapes x kv-head configs (the analytic formula must hold for
    # MQA/GQA alike); fp16 wire => itemsize 2 in the analytics
    CONFIGS = [
        dict(B=1, Sc=6, num_kv_heads=2, head_dim=16, ratio=0.5),
        dict(B=3, Sc=10, num_kv_heads=1, head_dim=32, ratio=0.25),
        dict(B=2, Sc=17, num_kv_heads=4, head_dim=8, ratio=1.0),
    ]

    @pytest.mark.parametrize("spec", CONFIGS)
    def test_measured_bytes_match_analytics_fp16(self, pair, tok, spec):
        cfg0, sender, _ = pair
        cfg = dataclasses.replace(cfg0, num_kv_heads=spec["num_kv_heads"],
                                  num_heads=4, head_dim=spec["head_dim"])
        params = tfm.init_params(cfg, jax.random.PRNGKey(2))
        ctx = jax.random.randint(jax.random.PRNGKey(3),
                                 (spec["B"], spec["Sc"]), 4, cfg.vocab_size)
        kv, _ = core.sender_prefill(params, cfg, ctx)
        kvcfg = KVCommConfig(ratio=spec["ratio"], selector="prior_only")
        select = core.make_selection(cfg, kvcfg)
        t = SerializedTransport(wire_dtype="float16")
        t.send(cfg, kvcfg, kv, select)
        M = int(np.asarray(select).sum())
        expect = core.kv_wire_bytes(cfg, spec["B"], spec["Sc"], M,
                                    itemsize=2)
        assert t.total_bytes == expect
        assert t.last.layers == M

    def test_int8_wire_smaller_than_fp16_and_lossy_but_close(self, pair,
                                                             tok):
        cfg, sender, _ = pair
        ctx = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 4,
                                 cfg.vocab_size)
        kv, _ = core.sender_prefill(sender, cfg, ctx)
        kvcfg = KVCommConfig(ratio=0.5, selector="prior_only")
        select = core.make_selection(cfg, kvcfg)
        t16 = SerializedTransport("float16")
        t8 = SerializedTransport("int8")
        sh16 = t16.send(cfg, kvcfg, kv, select)
        sh8 = t8.send(cfg, kvcfg, kv, select)
        assert t8.total_bytes < t16.total_bytes
        # packed hand-over: the payload IS the selected layers
        assert sh16.layers == sh8.layers == tuple(
            np.nonzero(np.asarray(select))[0])
        a = np.asarray(sh16.packed_kv["k"])
        b = np.asarray(sh8.packed_kv["k"])
        # int8 symmetric quant: ~1% of the dynamic range
        assert float(np.max(np.abs(a - b))) < 0.02 * float(np.max(np.abs(a)))

    def test_roundtrip_preserves_selected_unselected_zero(self, pair, tok):
        cfg, sender, _ = pair
        ctx = jax.random.randint(jax.random.PRNGKey(3), (1, 6), 4,
                                 cfg.vocab_size)
        kv, _ = core.sender_prefill(sender, cfg, ctx)
        select = jnp.array([True, False, False, True])
        # legacy dense hand-over: scattered back with zeros at non-selected
        t = SerializedTransport("float32", packed=False)
        shared = t.send(cfg, KVCommConfig(), kv, select)
        assert not shared.is_packed
        np.testing.assert_array_equal(np.asarray(shared.kv["k"][0]),
                                      np.asarray(kv["k"][0]))
        np.testing.assert_array_equal(np.asarray(shared.kv["k"][3]),
                                      np.asarray(kv["k"][3]))
        assert not np.any(np.asarray(shared.kv["k"][1]))
        assert not np.any(np.asarray(shared.kv["v"][2]))
        # packed hand-over densifies to exactly the same view
        tp = SerializedTransport("float32")
        dense = tp.send(cfg, KVCommConfig(), kv, select).to_dense()
        np.testing.assert_array_equal(np.asarray(dense.kv["k"]),
                                      np.asarray(shared.kv["k"]))
        np.testing.assert_array_equal(np.asarray(dense.kv["v"]),
                                      np.asarray(shared.kv["v"]))

    def test_int8_handles_ssm_state_leaves(self, tok):
        """SSM state leaves are rank 3-4, not the 5-D KV stack — the int8
        per-layer quantizer must reduce over every non-layer axis."""
        from repro.configs.registry import get_config
        cfg = dataclasses.replace(get_config("rwkv6-1.6b").reduced(),
                                  dtype="float32")
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        sess = CommSession(Agent("s", cfg, params, tok),
                           Agent("r", cfg, params, tok),
                           SerializedTransport("int8"))
        rng = np.random.default_rng(0)
        ctx = rng.integers(2, cfg.vocab_size, (2, 8)).astype(np.int32)
        qry = rng.integers(2, cfg.vocab_size, (2, 4)).astype(np.int32)
        shared, _ = sess.share(ctx, KVCommConfig(ratio=0.5,
                                                 selector="prior_only"))
        out = sess.receiver.prefill(qry, shared, max_new=0)
        assert sess.transport.total_bytes > 0
        assert np.isfinite(np.asarray(out.logits)).all()

    def test_serialized_fp32_preds_match_inmemory(self, pair, batch, tok):
        """A lossless wire must not change a single prediction."""
        cfg, s, r = pair
        kvcfg = KVCommConfig(ratio=0.5, selector="prior_only")
        a = _session(cfg, s, r, tok).run("kvcomm", batch, kvcfg=kvcfg)
        b = _session(cfg, s, r, tok,
                     SerializedTransport("float32")).run(
            "kvcomm", batch, kvcfg=kvcfg)
        np.testing.assert_array_equal(a.preds, b.preds)


class TestMultiSender:
    def test_two_sender_session_matches_combine_senders(self, pair, tok):
        """Mailbox composition must be bit-exact against the §J reference
        (same prefixes, same joint mask, same concat order)."""
        cfg, sender, receiver = pair
        sess = _session(cfg, sender, receiver, tok)
        kvcfg = KVCommConfig(ratio=0.7, selector="prior_only")
        select = sess.selection(kvcfg)
        rng = np.random.default_rng(0)
        c1 = rng.integers(4, cfg.vocab_size, (2, 6)).astype(np.int32)
        c2 = rng.integers(4, cfg.vocab_size, (2, 9)).astype(np.int32)

        h1 = sess.attach_sender(sess.sender, name="A")
        h2 = sess.attach_sender(sess.sender, name="B")
        h1.send(c1, kvcfg, select=select)
        h2.send(c2, kvcfg, select=select)
        combined = sess.combined()

        # reference: direct protocol-level composition (dense view)
        kv1, _, p1 = sess.sender.export_kv(c1)
        kv2, _, p2 = sess.sender.export_kv(c2)
        ref = core.combine_senders([
            SharedKV(kv=kv1, select=select, prefix_len=p1,
                     pos_mode=kvcfg.pos_mode),
            SharedKV(kv=kv2, select=select, prefix_len=p2,
                     pos_mode=kvcfg.pos_mode)])
        assert combined.prefix_len == ref.prefix_len == p1 + p2
        # the packed mailbox composition carries exactly the selected
        # layers of the dense reference
        assert combined.is_packed
        idx = np.nonzero(np.asarray(select))[0]
        np.testing.assert_array_equal(np.asarray(combined.packed_kv["k"]),
                                      np.asarray(ref.kv["k"])[idx])
        np.testing.assert_array_equal(np.asarray(combined.packed_kv["v"]),
                                      np.asarray(ref.kv["v"])[idx])
        np.testing.assert_array_equal(np.asarray(combined.select),
                                      np.asarray(ref.select))
        # and the two views drive the receiver to identical logits
        qry0 = rng.integers(4, cfg.vocab_size, (2, 4)).astype(np.int32)
        a = sess.receiver.prefill(qry0, combined, max_new=0)
        b = sess.receiver.prefill(qry0, ref, max_new=0)
        np.testing.assert_allclose(np.asarray(a.logits),
                                   np.asarray(b.logits), atol=2e-5)
        # and the receiver can consume it
        qry = rng.integers(4, cfg.vocab_size, (2, 4)).astype(np.int32)
        out = sess.receiver.prefill(qry, combined, max_new=0)
        assert np.isfinite(np.asarray(out.logits)).all()


class TestFacadeParity:
    """Old CommEngine surface == new CommSession path, prediction-for-
    prediction and byte-for-byte."""

    @pytest.mark.parametrize("method", ["kvcomm", "baseline", "skyline",
                                        "nld"])
    def test_preds_and_bytes_identical(self, pair, batch, tok, method):
        cfg, s, r = pair
        eng = CommEngine(cfg, s, r, tok)
        sess = _session(cfg, s, r, tok)
        kw = {}
        if method == "kvcomm":
            scores_e = eng.calibrate(batch["context"][:1],
                                     batch["query"][:1])
            scores_s = sess.calibrate(batch["context"][:1],
                                      batch["query"][:1])
            np.testing.assert_allclose(np.asarray(scores_e),
                                       np.asarray(scores_s))
            kw = dict(kvcfg=KVCommConfig(ratio=0.5, alpha=0.7),
                      scores=scores_s)
        a = eng.run(method, batch, nld_tokens=4, **kw)
        b = sess.run(method, batch, nld_tokens=4, **kw)
        np.testing.assert_array_equal(a.preds, b.preds)
        assert a.wire_bytes == b.wire_bytes
        assert a.flops == b.flops

    def test_channel_log_compatible(self, pair, batch, tok):
        cfg, s, r = pair
        eng = CommEngine(cfg, s, r, tok)
        eng.run("kvcomm", batch,
                kvcfg=KVCommConfig(ratio=0.5, selector="prior_only"))
        assert len(eng.channel.log) == 1
        rec = eng.channel.log[-1]
        assert rec.kind == "kv" and rec.layers == 2
        assert eng.channel.total_bytes == rec.n_bytes

    def test_selection_cache_frozen_per_task(self, pair, batch, tok):
        cfg, s, r = pair
        sess = _session(cfg, s, r, tok)
        scores = sess.calibrate(batch["context"][:1], batch["query"][:1],
                                key="t1")
        kvcfg = KVCommConfig(ratio=0.5, alpha=0.7)
        s1 = sess.selection(kvcfg, scores=scores, key="t1")
        s2 = sess.selection(kvcfg, key="t1")     # cache hit, no scores given
        assert s1 is s2
        r1 = sess.run("kvcomm", batch, kvcfg=kvcfg, calib_key="t1")
        np.testing.assert_array_equal(r1.extras["select"], np.asarray(s1))

    def test_explicit_scores_bypass_selection_cache(self, pair, batch, tok):
        """Fresh scores must not be silently ignored on a cache hit."""
        cfg, s, r = pair
        sess = _session(cfg, s, r, tok)
        kvcfg = KVCommConfig(ratio=0.5, alpha=1.0)
        low_first = jnp.linspace(0.0, 1.0, cfg.attn_layer_count)
        high_first = low_first[::-1]
        s1 = sess.selection(kvcfg, scores=low_first, key="t")
        s2 = sess.selection(kvcfg, scores=high_first, key="t")
        assert not np.array_equal(np.asarray(s1), np.asarray(s2))
        # and the score-less call now serves the refreshed selection
        np.testing.assert_array_equal(
            np.asarray(sess.selection(kvcfg, key="t")), np.asarray(s2))


class TestGeneration:
    def test_stream_matches_batched_generate(self, pair, batch, tok):
        cfg, s, r = pair
        sess = _session(cfg, s, r, tok)
        kvcfg = KVCommConfig(ratio=0.5, selector="prior_only")
        shared, _ = sess.share(batch["context"], kvcfg)
        toks = sess.generate(batch["query"], shared, max_new=4)
        streamed = np.stack(list(sess.stream(batch["query"], shared,
                                             max_new=4)), axis=1)
        np.testing.assert_array_equal(toks, streamed)
