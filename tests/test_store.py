"""The paged prefix store: split/rebuild bit-exactness against the unpaged
wire codec, pool eviction/pinning invariants (property-tested), content-hash
collision guards, and the dedup acceptance bar — a second receiver sharing
the same sender context ships only the novel pages."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import core
from repro.comm import InMemoryTransport, RemoteTransport
from repro.comm.transport import roundtrip_kv
from repro.core.protocol import gather_mapped, gather_selected
from repro.core.types import KVCommConfig
from repro.store import (BlockTable, Page, PagePool, PagePoolError,
                         PageStore, PoolFullError, page_id_for,
                         rebuild_payload, rebuild_shared, split_payload)

WIRES = ["float32", "float16", "int8"]
RATIOS = [0.3, 0.5, 1.0]


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def sender_kv(tiny_cfg, tiny_params):
    ctx = jax.random.randint(jax.random.PRNGKey(1), (2, 7), 4,
                             tiny_cfg.vocab_size)
    kv, _ = core.sender_prefill(tiny_params, tiny_cfg, ctx)
    return kv


def _payload(cfg, kv, ratio):
    kvcfg = KVCommConfig(ratio=ratio, selector="prior_only")
    select = core.make_selection(cfg, kvcfg)
    payload = gather_selected(kv, jnp.asarray(select))
    return payload, core.selected_layer_ids(select), np.asarray(select)


def _mk_page(pid="x", layer=0, nbytes=64, start=0):
    """A hand-built page for pool tests (content hash irrelevant there —
    the pool keys purely on page_id)."""
    side = max(nbytes // 2, 1)
    k = np.zeros((1, side, 1, 1), np.int8)
    v = np.zeros((1, side, 1, 1), np.int8)
    return Page(page_id=pid, layer=layer, start=start, length=side,
                k=k, v=v)


# ---------------------------------------------------------------------------
# split/rebuild bit-exactness
# ---------------------------------------------------------------------------
class TestSplitRebuild:
    @pytest.mark.parametrize("wire", WIRES)
    @pytest.mark.parametrize("ratio", RATIOS)
    @pytest.mark.parametrize("page_len", [3, 4, 16])
    def test_roundtrip_matches_unpaged_codec(self, tiny_cfg, sender_kv,
                                             wire, ratio, page_len):
        """trim(concat(split(x))) == x: the rebuilt compute-dtype payload
        equals what the unpaged wire codec produces for the same transfer
        — paging is invisible, whatever the ratio / wire / page size."""
        payload, layers, select = _payload(tiny_cfg, sender_kv, ratio)
        ref, _ = roundtrip_kv(payload, wire, payload["k"].dtype)
        table, pages = split_payload(payload, layers=layers, select=select,
                                     page_len=page_len, wire_dtype=wire)
        got = rebuild_shared(table, {p.page_id: p for p in pages})
        assert got.layers == layers
        assert got.prefix_len == int(payload["k"].shape[2])
        for part in ("k", "v"):
            np.testing.assert_array_equal(np.asarray(got.packed_kv[part]),
                                          np.asarray(ref[part]))

    def test_hetero_payload_roundtrips(self, tiny_cfg, sender_kv):
        """A mapped (heterogeneous) payload pages by RECEIVER slot and
        keeps its src_layers provenance through the table."""
        assignment = core.get_layer_map("depth_proportional").assign(
            (0, 1, 3), num_src_layers=4, num_dst_layers=6)
        payload = gather_mapped(sender_kv, assignment)
        ref, _ = roundtrip_kv(payload, "float32", payload["k"].dtype)
        table, pages = split_payload(
            payload, layers=tuple(assignment.dst),
            select=np.asarray(assignment.dst_mask()), page_len=4,
            wire_dtype="float32", src_layers=tuple(assignment.src))
        got = rebuild_shared(table, {p.page_id: p for p in pages})
        assert got.layers == tuple(assignment.dst)
        assert got.src_layers == tuple(assignment.src)
        for part in ("k", "v"):
            np.testing.assert_array_equal(np.asarray(got.packed_kv[part]),
                                          np.asarray(ref[part]))

    @pytest.mark.parametrize("page_len", [3, 5, 7])
    def test_pages_are_fixed_size_and_tail_padded(self, tiny_cfg,
                                                  sender_kv, page_len):
        payload, layers, select = _payload(tiny_cfg, sender_kv, 0.5)
        Sc = int(payload["k"].shape[2])
        table, pages = split_payload(payload, layers=layers, select=select,
                                     page_len=page_len,
                                     wire_dtype="float32")
        assert table.pages_per_slot == -(-Sc // page_len)
        for pg in pages:
            assert pg.k.shape[1] == page_len       # fixed-size block
            assert pg.nbytes == table.page_nbytes
            if pg.start + page_len > Sc:           # the tail page
                assert pg.length == Sc - pg.start
                assert not np.any(pg.k[:, pg.length:])   # zero padding
                assert not np.any(pg.v[:, pg.length:])
            else:
                assert pg.length == page_len

    def test_bucket_gather_equals_pad_prefix(self, tiny_cfg, sender_kv):
        """The scheduler's page gather at a bucket == pad_prefix of the
        materialized view, bit for bit (the paged-admission parity
        argument)."""
        payload, layers, select = _payload(tiny_cfg, sender_kv, 0.5)
        for wire in ("float32", "int8"):
            store = PageStore(page_len=4)
            table, _, _ = store.ingest(payload, layers=layers,
                                       select=select, wire_dtype=wire)
            bucket = 16
            got = store.gather_prefix(table, bucket)
            ref = core.pad_prefix(store.materialize(table), bucket)
            for part in ("k", "v"):
                np.testing.assert_array_equal(
                    np.asarray(got[part]), np.asarray(ref.packed_kv[part]))
            with pytest.raises(ValueError):
                store.gather_prefix(table, table.prefix_len - 1)

    def test_missing_page_raises_keyerror(self, tiny_cfg, sender_kv):
        payload, layers, select = _payload(tiny_cfg, sender_kv, 0.5)
        table, pages = split_payload(payload, layers=layers, select=select,
                                     page_len=4, wire_dtype="float32")
        have = {p.page_id: p for p in pages[:-1]}
        with pytest.raises(KeyError):
            rebuild_payload(table, have)

    def test_table_meta_roundtrips(self, tiny_cfg, sender_kv):
        import json
        payload, layers, select = _payload(tiny_cfg, sender_kv, 0.5)
        table, _ = split_payload(payload, layers=layers, select=select,
                                 page_len=4, wire_dtype="int8")
        meta = json.loads(json.dumps(table.meta()))   # wire-safe
        back = BlockTable.from_meta(meta, scales=table.scales)
        assert back == dataclasses.replace(table, scales=back.scales)
        np.testing.assert_array_equal(back.scales["k"], table.scales["k"])


# ---------------------------------------------------------------------------
# content hashing
# ---------------------------------------------------------------------------
class TestContentHash:
    def test_same_content_same_span_collides_deliberately(self):
        k = np.arange(32, dtype=np.float32).reshape(1, 4, 2, 4)
        v = k + 1
        a = page_id_for(0, 0, 4, k, v, wire_dtype="float32")
        b = page_id_for(0, 0, 4, k.copy(), v.copy(), wire_dtype="float32")
        assert a == b                                   # that IS the dedup

    def test_differing_bytes_span_layer_or_salt_differ(self):
        k = np.arange(32, dtype=np.float32).reshape(1, 4, 2, 4)
        v = k + 1
        base = page_id_for(0, 0, 4, k, v, wire_dtype="float32")
        k2 = k.copy()
        k2[0, 0, 0, 0] += 1
        assert page_id_for(0, 0, 4, k2, v, wire_dtype="float32") != base
        assert page_id_for(1, 0, 4, k, v, wire_dtype="float32") != base
        assert page_id_for(0, 4, 4, k, v, wire_dtype="float32") != base
        assert page_id_for(0, 0, 3, k, v, wire_dtype="float32") != base
        assert page_id_for(0, 0, 4, k, v, wire_dtype="float16") != base
        assert page_id_for(0, 0, 4, k, v, wire_dtype="float32",
                           salt=b"s") != base

    def test_int8_scale_salt_prevents_cross_scale_collisions(self, tiny_cfg,
                                                             sender_kv):
        """Two payloads quantizing to the SAME int8 codes under different
        scales decode differently — the per-layer scale salt must keep
        their pages distinct."""
        payload, layers, select = _payload(tiny_cfg, sender_kv, 0.5)
        doubled = {p: jnp.asarray(payload[p]) * 2.0 for p in ("k", "v")}
        t1, _ = split_payload(payload, layers=layers, select=select,
                              page_len=4, wire_dtype="int8")
        t2, _ = split_payload(doubled, layers=layers, select=select,
                              page_len=4, wire_dtype="int8")
        assert not set(t1.all_ids()) & set(t2.all_ids())


# ---------------------------------------------------------------------------
# pool invariants
# ---------------------------------------------------------------------------
class TestPagePool:
    def test_lru_eviction_order(self):
        pool = PagePool(capacity_bytes=3 * 64, policy="lru")
        for pid in ("a", "b", "c"):
            pool.put(_mk_page(pid))
        pool.get("a")                   # touch: a is now most recent
        pool.put(_mk_page("d"))        # evicts b (oldest untouched)
        assert "b" not in pool and set(pool.ids()) == {"a", "c", "d"}
        assert pool.evictions == 1

    def test_priority_eviction_lowest_first_lru_tiebreak(self):
        pool = PagePool(capacity_bytes=3 * 64, policy="priority")
        pool.put(_mk_page("a"), priority=1.0)
        pool.put(_mk_page("b"), priority=0.0)
        pool.put(_mk_page("c"), priority=0.0)
        pool.put(_mk_page("d"), priority=2.0)   # evicts b (lowest, oldest)
        assert "b" not in pool
        pool.put(_mk_page("e"), priority=2.0)   # evicts c
        assert "c" not in pool and set(pool.ids()) == {"a", "d", "e"}

    def test_pinned_pages_survive_eviction(self):
        pool = PagePool(capacity_bytes=2 * 64)
        pool.put(_mk_page("a"), pin=True)
        pool.put(_mk_page("b"))
        pool.put(_mk_page("c"))         # must evict b, never pinned a
        assert "a" in pool and "b" not in pool

    def test_all_pinned_raises_pool_full(self):
        pool = PagePool(capacity_bytes=2 * 64)
        pool.put(_mk_page("a"), pin=True)
        pool.put(_mk_page("b"), pin=True)
        with pytest.raises(PoolFullError):
            pool.put(_mk_page("c"))
        assert pool.used_bytes == 2 * 64   # failed insert left no residue

    def test_oversize_page_refused(self):
        pool = PagePool(capacity_bytes=32)
        with pytest.raises(PoolFullError):
            pool.put(_mk_page("big", nbytes=64))

    def test_unbalanced_unpin_and_absent_pin_raise(self):
        pool = PagePool()
        pool.put(_mk_page("a"))
        with pytest.raises(PagePoolError):
            pool.unpin(["a"])
        with pytest.raises(PagePoolError):
            pool.pin(["ghost"])

    @given(st.lists(st.tuples(st.integers(0, 7), st.booleans()),
                    min_size=1, max_size=40))
    @settings(max_examples=60, deadline=None)
    def test_eviction_property_capacity_and_pins_respected(self, ops):
        """Random insert/touch streams: used_bytes never exceeds capacity,
        pinned pages are never evicted, and accounting stays exact."""
        pool = PagePool(capacity_bytes=4 * 64)
        pinned = set()
        try:
            for i, (n, pin) in enumerate(ops):
                pid = f"p{n}"
                novel = pool.put(_mk_page(pid), pin=pin)
                if pin:
                    pinned.add(pid)
                assert pool.used_bytes <= pool.capacity_bytes
                assert pool.used_bytes == 64 * len(pool)
                assert all(p in pool for p in pinned)
        except PoolFullError:
            assert len(pinned) >= 4     # only an all-pinned pool refuses

    @given(st.lists(st.integers(1, 3), min_size=1, max_size=10))
    @settings(max_examples=40, deadline=None)
    def test_pin_refcount_property(self, counts):
        """pin(n) then unpin(n) is balanced; unpin(n+1) raises; a page is
        evictable exactly when its refcount is zero."""
        pool = PagePool(capacity_bytes=1 << 20)
        for i, n in enumerate(counts):
            pid = f"p{i}"
            pool.put(_mk_page(pid))
            pool.pin([pid] * n)
            assert pool.pins[pid] == n
            pool.unpin([pid] * (n - 1))
            assert pool.pins[pid] == 1
            pool.unpin([pid])
            assert pid not in pool.pins
            with pytest.raises(PagePoolError):
                pool.unpin([pid])


# ---------------------------------------------------------------------------
# the store: ingest/dedup/lifecycle
# ---------------------------------------------------------------------------
class TestPageStore:
    def test_second_ingest_ships_nothing(self, tiny_cfg, sender_kv):
        payload, layers, select = _payload(tiny_cfg, sender_kv, 0.5)
        store = PageStore(page_len=4)
        t1, novel1, nb1 = store.ingest(payload, layers=layers,
                                       select=select, wire_dtype="float32")
        assert len(novel1) == t1.num_pages and nb1 > 0
        t2, novel2, nb2 = store.ingest(payload, layers=layers,
                                       select=select, wire_dtype="float32")
        assert novel2 == [] and nb2 == 0
        assert t2.all_ids() == t1.all_ids()

    def test_overlapping_context_ships_only_novel_pages(self, tiny_cfg,
                                                        tiny_params):
        """The acceptance bar: a second request whose context EXTENDS the
        first shares every full page of the common prefix — only the new
        tail (and the page the old tail padding sat in) crosses."""
        page = 4
        ctx = jax.random.randint(jax.random.PRNGKey(5), (1, 8), 4,
                                 tiny_cfg.vocab_size)
        ext = jnp.concatenate(
            [ctx, jax.random.randint(jax.random.PRNGKey(6), (1, 4), 4,
                                     tiny_cfg.vocab_size)], axis=1)
        kv1, _ = core.sender_prefill(tiny_params, tiny_cfg, ctx)
        kv2, _ = core.sender_prefill(tiny_params, tiny_cfg, ext)
        _, layers, select = _payload(tiny_cfg, kv1, 0.5)
        p1 = gather_selected(kv1, jnp.asarray(select))
        p2 = gather_selected(kv2, jnp.asarray(select))
        store = PageStore(page_len=page)
        t1, novel1, _ = store.ingest(p1, layers=layers, select=select,
                                     wire_dtype="float32")
        t2, novel2, _ = store.ingest(p2, layers=layers, select=select,
                                     wire_dtype="float32")
        # the 8-token prefix = 2 full pages per layer, shared verbatim; the
        # extension adds 1 page per layer (12 tokens / page 4 = 3 pages)
        assert len(novel1) == t1.num_pages
        assert len(novel2) == t2.num_pages - 2 * len(layers)
        assert set(t1.all_ids()) < set(t2.all_ids())

    def test_release_makes_pages_evictable(self, tiny_cfg, sender_kv):
        payload, layers, select = _payload(tiny_cfg, sender_kv, 0.5)
        probe, _ = split_payload(payload, layers=layers, select=select,
                                 page_len=4, wire_dtype="float32")
        store = PageStore(page_len=4,
                          capacity_bytes=probe.num_pages
                          * probe.page_nbytes)
        table, _, _ = store.ingest(payload, layers=layers, select=select,
                                   wire_dtype="float32")
        assert store.stats().pinned_bytes == store.stats().used_bytes
        # a full, fully-pinned pool refuses a new page
        with pytest.raises(PoolFullError):
            store.pool.put(_mk_page("fresh",
                                    nbytes=probe.page_nbytes))
        store.release(table)
        assert store.stats().pinned_bytes == 0
        assert store.pool.put(_mk_page("fresh",
                                       nbytes=probe.page_nbytes))

    def test_dedup_summary_and_fanout(self, tiny_cfg, tiny_params, tok):
        """Two receivers sharing ONE sender context: the second receiver's
        transfer dedups against the first's pages — measured bytes drop by
        the full shared-page fraction (here: all of it)."""
        from repro.comm import Agent, CommSession
        kvcfg = KVCommConfig(ratio=0.5, selector="prior_only")
        ctx = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (1, 8),
                                            4, tiny_cfg.vocab_size))
        store = PageStore(page_len=4)     # ONE receiver-side pool
        sender = Agent("s", tiny_cfg, tiny_params, tok)
        recs = []
        for i in range(2):
            t = InMemoryTransport(store=store)
            sess = CommSession(sender,
                               Agent(f"r{i}", tiny_cfg, tiny_params, tok),
                               t)
            sess.share(ctx, kvcfg)
            recs.append(t.last)
            s = sess.dedup_summary()
            assert s["transfers"] == 1
            assert s["pages_total"] == recs[0].pages_total
        assert recs[0].pages_sent == recs[0].pages_total
        assert recs[1].pages_sent == 0 and recs[1].hit_rate == 1.0
        assert recs[1].n_bytes == 0


# ---------------------------------------------------------------------------
# the paged wire's tamper guard
# ---------------------------------------------------------------------------
class TestPagedWireVerification:
    def test_tampered_page_is_refused(self, tiny_cfg, sender_kv):
        from repro.comm.remote import (PayloadMismatchError, decode_frame,
                                       encode_frame)
        from repro.store.wire import (PagedReceiver, encode_page_data,
                                      encode_page_query)
        payload, layers, select = _payload(tiny_cfg, sender_kv, 0.5)
        table, pages = split_payload(payload, layers=layers, select=select,
                                     page_len=4, wire_dtype="float32")
        store = PageStore(page_len=4)
        rx = PagedReceiver(store)
        _, meta, arrays = decode_frame(encode_page_query(0, table))
        rx.handle_query(meta, arrays)
        pages[0].k[0, 0, 0, 0] += 1.0     # bit-flip AFTER hashing
        frame, _ = encode_page_data(0, pages, wire_dtype="float32")
        _, meta, arrays = decode_frame(frame)
        with pytest.raises(PayloadMismatchError, match="hash mismatch"):
            rx.handle_data(meta, arrays)
        assert len(store.pool) == 0       # nothing poisoned the pool

    def test_data_without_query_is_refused(self, tiny_cfg, sender_kv):
        from repro.comm.remote import PayloadMismatchError, decode_frame
        from repro.store.wire import PagedReceiver, encode_page_data
        payload, layers, select = _payload(tiny_cfg, sender_kv, 0.5)
        _, pages = split_payload(payload, layers=layers, select=select,
                                 page_len=4, wire_dtype="float32")
        rx = PagedReceiver(PageStore(page_len=4))
        frame, _ = encode_page_data(7, pages, wire_dtype="float32")
        _, meta, arrays = decode_frame(frame)
        with pytest.raises(PayloadMismatchError, match="unknown exchange"):
            rx.handle_data(meta, arrays)
