"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Every Pallas kernel is swept over shapes and dtypes and asserted against
ref.py; plus hypothesis property tests on the flash-decode LSE-combine
(the distributed long-context decode correctness hinges on it).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape).astype(dtype)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,Sq,Sc,Hq,Hkv,D", [
        (1, 8, 0, 1, 1, 16),
        (2, 24, 16, 4, 2, 32),
        (1, 17, 5, 6, 3, 64),     # ragged, needs padding
        (2, 32, 32, 8, 8, 16),    # MHA
        (1, 64, 0, 4, 1, 128),    # MQA, no context
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, B, Sq, Sc, Hq, Hkv, D, dtype):
        ks = jax.random.split(KEY, 3)
        Skv = Sc + Sq
        q = _rand(ks[0], (B, Sq, Hq, D), dtype)
        k = _rand(ks[1], (B, Skv, Hkv, D), dtype)
        v = _rand(ks[2], (B, Skv, Hkv, D), dtype)
        out, mass = ops.flash_attention(
            q, k, v, context_len=Sc, q_offset=Sc, collect_mass=Sc > 0,
            blk_q=8, blk_k=8)
        rout, rmass = ref.mha_reference(
            q.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), context_len=Sc, q_offset=Sc,
            collect_mass=Sc > 0)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(rout, np.float32),
                                   **_tol(dtype))
        if Sc > 0:
            np.testing.assert_allclose(np.asarray(mass),
                                       np.asarray(rmass), **_tol(dtype))

    @pytest.mark.parametrize("window", [1, 4, 9, 64])
    def test_sliding_window(self, window):
        ks = jax.random.split(KEY, 3)
        q = _rand(ks[0], (1, 32, 2, 16))
        k = _rand(ks[1], (1, 32, 2, 16))
        v = _rand(ks[2], (1, 32, 2, 16))
        out, _ = ops.flash_attention(q, k, v, window=window, blk_q=8,
                                     blk_k=8)
        rout, _ = ref.mha_reference(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(rout),
                                   atol=2e-5, rtol=2e-5)

    def test_noncausal(self):
        ks = jax.random.split(KEY, 3)
        q = _rand(ks[0], (2, 16, 2, 16))
        k = _rand(ks[1], (2, 16, 2, 16))
        v = _rand(ks[2], (2, 16, 2, 16))
        out, _ = ops.flash_attention(q, k, v, causal=False, blk_q=8,
                                     blk_k=8)
        rout, _ = ref.mha_reference(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(rout),
                                   atol=2e-5, rtol=2e-5)

    def test_mass_excludes_self_segment(self):
        """mass sums only over the context prefix, never self tokens."""
        ks = jax.random.split(KEY, 3)
        Sc, Sq = 12, 8
        q = _rand(ks[0], (1, Sq, 2, 16))
        k = _rand(ks[1], (1, Sc + Sq, 2, 16))
        v = _rand(ks[2], (1, Sc + Sq, 2, 16))
        _, mass = ops.flash_attention(q, k, v, context_len=Sc, q_offset=Sc,
                                      collect_mass=True, blk_q=8, blk_k=8)
        assert 0.0 < float(mass[0]) < 1.0


class TestFlashDecode:
    @pytest.mark.parametrize("B,S,Hq,Hkv,D", [
        (1, 16, 1, 1, 16),
        (2, 64, 4, 2, 32),
        (3, 40, 8, 8, 64),      # ragged
        (2, 128, 8, 2, 128),
    ])
    def test_matches_oracle(self, B, S, Hq, Hkv, D):
        ks = jax.random.split(KEY, 4)
        q = _rand(ks[0], (B, Hq, D))
        k = _rand(ks[1], (B, S, Hkv, D))
        v = _rand(ks[2], (B, S, Hkv, D))
        kv_len = jax.random.randint(ks[3], (B,), 1, S + 1)
        out = ops.decode_attention(q, k, v, kv_len, blk_k=8)
        rout = ref.decode_reference(q, k, v, kv_len=kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(rout),
                                   atol=2e-5, rtol=2e-5)

    def test_window(self):
        ks = jax.random.split(KEY, 3)
        q = _rand(ks[0], (2, 4, 16))
        k = _rand(ks[1], (2, 32, 2, 16))
        v = _rand(ks[2], (2, 32, 2, 16))
        out = ops.decode_attention(q, k, v, 32, window=5, blk_k=8)
        rout = ref.decode_reference(q, k, v, kv_len=32, window=5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(rout),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("S,blk_k", [
        (40, 16),    # S not a multiple of blk_k — tail block padded
        (8, 256),    # S < blk_k — block clamped to S
        (23, 7),     # odd block over odd length
        (1, 8),      # single-position cache
    ])
    def test_unaligned_lengths(self, S, blk_k):
        """Regression: _call used to assert Skv % blk_k == 0; now the tail
        is padded and masked instead, so ANY (cache length, block) pair is
        legal."""
        ks = jax.random.split(KEY, 4)
        q = _rand(ks[0], (2, 4, 16))
        k = _rand(ks[1], (2, S, 2, 16))
        v = _rand(ks[2], (2, S, 2, 16))
        kv_len = jax.random.randint(ks[3], (2,), 1, S + 1)
        out = ops.decode_attention(q, k, v, kv_len, blk_k=blk_k)
        rout = ref.decode_reference(q, k, v, kv_len=kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(rout),
                                   atol=2e-5, rtol=2e-5)

    def test_zero_length_rows_return_zeros(self):
        """Regression: rows with kv_len == 0 (dead serving slots) return
        defined zeros instead of 0/0 NaNs."""
        ks = jax.random.split(KEY, 3)
        q = _rand(ks[0], (3, 4, 16))
        k = _rand(ks[1], (3, 16, 2, 16))
        v = _rand(ks[2], (3, 16, 2, 16))
        kv_len = jnp.array([0, 9, 0], jnp.int32)
        out = np.asarray(ops.decode_attention(q, k, v, kv_len, blk_k=8))
        assert np.all(np.isfinite(out))
        np.testing.assert_array_equal(out[[0, 2]], 0.0)
        rout = ref.decode_reference(q, k, v, kv_len=kv_len)
        np.testing.assert_allclose(out[1], np.asarray(rout)[1],
                                   atol=2e-5, rtol=2e-5)

    @given(st.integers(1, 4), st.integers(2, 6))
    @settings(max_examples=10, deadline=None)
    def test_sharded_combine_equals_full(self, n_shards, blocks):
        """Flash-decode partials LSE-combined across shards == full decode —
        the invariant behind the distributed 500k-token cache."""
        S = 8 * blocks * n_shards
        ks = jax.random.split(KEY, 3)
        q = _rand(ks[0], (2, 4, 32))
        k = _rand(ks[1], (2, S, 2, 32))
        v = _rand(ks[2], (2, S, 2, 32))
        per = S // n_shards
        os_, ms_, ls_ = [], [], []
        for i in range(n_shards):
            o, m, l = ops.decode_attention_partials(
                q, k[:, i * per:(i + 1) * per],
                v[:, i * per:(i + 1) * per], per, blk_k=8)
            os_.append(o), ms_.append(m), ls_.append(l)
        comb = ref.combine_decode_partials(
            jnp.stack(os_), jnp.stack(ms_), jnp.stack(ls_))
        full = ref.decode_reference(q, k, v, kv_len=S)
        np.testing.assert_allclose(np.asarray(comb), np.asarray(full),
                                   atol=2e-5, rtol=2e-5)


class TestWKV6:
    @pytest.mark.parametrize("B,T,H,hd,blk", [
        (1, 16, 1, 8, 8),
        (2, 40, 3, 16, 16),    # ragged T
        (1, 64, 2, 32, 32),
    ])
    def test_matches_oracle(self, B, T, H, hd, blk):
        ks = jax.random.split(KEY, 6)
        r = _rand(ks[0], (B, T, H, hd))
        k = _rand(ks[1], (B, T, H, hd))
        v = _rand(ks[2], (B, T, H, hd))
        w = jax.nn.sigmoid(_rand(ks[3], (B, T, H, hd)))
        u = _rand(ks[4], (H, hd))
        s0 = _rand(ks[5], (B, H, hd, hd))
        y, sf = ops.wkv6_scan(r, k, v, w, u, s0, blk_t=blk)
        ry, rsf = ref.wkv6_reference(r, k, v, w, u, s0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ry),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(sf), np.asarray(rsf),
                                   atol=1e-4, rtol=1e-4)

    def test_chunking_invariance(self):
        """Same result regardless of time-chunk size."""
        ks = jax.random.split(KEY, 6)
        B, T, H, hd = 1, 32, 2, 16
        r = _rand(ks[0], (B, T, H, hd))
        k = _rand(ks[1], (B, T, H, hd))
        v = _rand(ks[2], (B, T, H, hd))
        w = jax.nn.sigmoid(_rand(ks[3], (B, T, H, hd)))
        u = _rand(ks[4], (H, hd))
        s0 = jnp.zeros((B, H, hd, hd))
        y8, s8 = ops.wkv6_scan(r, k, v, w, u, s0, blk_t=8)
        y32, s32 = ops.wkv6_scan(r, k, v, w, u, s0, blk_t=32)
        np.testing.assert_allclose(np.asarray(y8), np.asarray(y32),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(s8), np.asarray(s32),
                                   atol=1e-5)

    def test_state_continuation(self):
        """Running [0:T/2] then [T/2:T] from the carried state == full run —
        the prefill/decode split and the state-sharing protocol rely on it."""
        ks = jax.random.split(KEY, 6)
        B, T, H, hd = 1, 32, 2, 16
        r = _rand(ks[0], (B, T, H, hd))
        k = _rand(ks[1], (B, T, H, hd))
        v = _rand(ks[2], (B, T, H, hd))
        w = jax.nn.sigmoid(_rand(ks[3], (B, T, H, hd)))
        u = _rand(ks[4], (H, hd))
        s0 = jnp.zeros((B, H, hd, hd))
        y_full, s_full = ref.wkv6_reference(r, k, v, w, u, s0)
        h = T // 2
        y1, s1 = ref.wkv6_reference(r[:, :h], k[:, :h], v[:, :h], w[:, :h],
                                    u, s0)
        y2, s2 = ref.wkv6_reference(r[:, h:], k[:, h:], v[:, h:], w[:, h:],
                                    u, s1)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), atol=1e-5)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                                   atol=1e-5)
