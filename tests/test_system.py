"""End-to-end system behaviour: train a tiny pair on the retrieval task and
verify the paper's headline structure emerges from the full pipeline
(trained-checkpoint accuracy levels are asserted by the benchmark suite;
here we train 250 quick steps and check structural behaviour)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import KVCommConfig
from repro.data.pipeline import synthetic_lm_iter
from repro.data.synthetic import SyntheticTask, TaskConfig
from repro.serving.engine import CommEngine
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import train


@pytest.fixture(scope="module")
def trained(tok):
    from repro.configs.registry import get_config
    cfg = dataclasses.replace(
        get_config("llama3.2-3b-pair"),
        num_layers=4, d_model=96, d_ff=256, num_heads=4, num_kv_heads=4,
        head_dim=24, vocab_size=tok.vocab_size, dtype="float32",
        remat=False, tie_embeddings=False)
    task = SyntheticTask(tok, TaskConfig("retrieval", num_facts=4, seed=0))
    it = synthetic_lm_iter(task, 32)
    opt = OptimizerConfig(lr=3e-3, total_steps=250, warmup_steps=25)
    state = train(cfg, opt, it, steps=250, log_every=0)
    eval_task = SyntheticTask(tok, TaskConfig("retrieval", num_facts=4,
                                              seed=99))
    return cfg, state.params, eval_task


class TestEndToEnd:
    def test_skyline_beats_baseline(self, trained, tok):
        cfg, params, task = trained
        eng = CommEngine(cfg, params, params, tok)
        b = task.batch(48)
        sky = eng.run("skyline", b)
        base = eng.run("baseline", b)
        assert sky.accuracy > base.accuracy

    def test_kvcomm_full_matches_skyline_and_uses_less_compute_partial(
            self, trained, tok):
        cfg, params, task = trained
        eng = CommEngine(cfg, params, params, tok)
        b = task.batch(48)
        sky = eng.run("skyline", b)
        full = eng.run("kvcomm", b,
                       kvcfg=KVCommConfig(ratio=1.0, selector="all"))
        np.testing.assert_array_equal(full.preds, sky.preds)
        part = eng.run("kvcomm", b,
                       kvcfg=KVCommConfig(ratio=0.5, selector="prior_only"))
        assert part.flops < sky.flops
        assert part.wire_bytes < full.wire_bytes

    def test_calibrated_selection_is_deterministic(self, trained, tok):
        cfg, params, task = trained
        eng = CommEngine(cfg, params, params, tok)
        b = task.batch(2)
        s1 = eng.calibrate(b["context"][:1], b["query"][:1])
        s2 = eng.calibrate(b["context"][:1], b["query"][:1])
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   atol=1e-6)

    def test_generation_loop(self, trained, tok):
        from repro import core
        from repro.core.types import SharedKV
        cfg, params, task = trained
        b = task.batch(2)
        kv, _ = core.sender_prefill(params, cfg,
                                    jnp.asarray(b["context"]))
        L = cfg.attn_layer_count
        shared = SharedKV(kv=kv, select=jnp.ones((L,), bool),
                          prefix_len=b["context"].shape[1])
        toks, cache = core.generate(params, cfg, jnp.asarray(b["query"]),
                                    shared, max_new=4)
        assert toks.shape == (2, 4)
        assert int(cache["len"]) == (b["context"].shape[1]
                                     + b["query"].shape[1] + 4)
