"""Unit + property tests for the paper's layer-selection strategy (§3.2)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.selection import (gaussian_prior, interp_scores, kendall_tau,
                                  normalize_scores, select_layers,
                                  selection_scores, topk_mask)
from repro.core.types import KVCommConfig


class TestGaussianPrior:
    def test_peak_at_mu(self):
        p = gaussian_prior(32, mu=16, sigma=10)
        assert int(jnp.argmax(p)) == 15  # layer index 16 is position 15

    def test_default_mu_is_midpoint(self):
        p = gaussian_prior(28)
        assert abs(int(jnp.argmax(p)) - 13) <= 1

    def test_bounds(self):
        p = gaussian_prior(48, sigma=10)
        assert float(jnp.max(p)) <= 1.0 + 1e-6
        assert float(jnp.min(p)) > 0.0

    def test_symmetry(self):
        p = np.asarray(gaussian_prior(31, mu=16, sigma=5))
        assert np.allclose(p, p[::-1], atol=1e-6)


class TestNormalize:
    def test_range(self):
        s = normalize_scores(jnp.array([3.0, 7.0, 5.0]))
        assert float(jnp.min(s)) == 0.0 and float(jnp.max(s)) == 1.0

    def test_batch_averaged(self):
        raw = jnp.array([[1.0, 3.0], [2.0, 2.0]])  # (L=2, B=2)
        s = normalize_scores(raw)
        assert s.shape == (2,)

    @given(st.lists(st.floats(-1e3, 1e3), min_size=2, max_size=64))
    @settings(max_examples=50, deadline=None)
    def test_property_range(self, vals):
        s = np.asarray(normalize_scores(jnp.array(vals, jnp.float32)))
        assert np.all(s >= -1e-6) and np.all(s <= 1.0 + 1e-6)


class TestSelection:
    @given(st.integers(2, 80), st.floats(0.05, 1.0), st.integers(0, 10_000))
    @settings(max_examples=60, deadline=None)
    def test_topk_count_property(self, L, ratio, seed):
        cfg = KVCommConfig(ratio=ratio, selector="random", seed=seed)
        mask = np.asarray(select_layers(None, L, cfg))
        assert mask.sum() == cfg.num_selected(L) == min(
            L, max(1, int(np.ceil(ratio * L))))

    def test_kvcomm_picks_top_scores_alpha1(self):
        scores = jnp.array([0.1, 0.9, 0.3, 0.8, 0.2, 0.0])
        cfg = KVCommConfig(ratio=0.5, alpha=1.0, selector="kvcomm")
        mask = np.asarray(select_layers(scores, 6, cfg))
        assert list(np.nonzero(mask)[0]) == [1, 2, 3]

    def test_alpha0_equals_prior_only(self):
        scores = jax.random.uniform(jax.random.PRNGKey(0), (32,))
        a = select_layers(scores, 32,
                          KVCommConfig(ratio=0.3, alpha=0.0,
                                       selector="kvcomm"))
        b = select_layers(None, 32,
                          KVCommConfig(ratio=0.3, selector="prior_only"))
        assert bool(jnp.all(a == b))

    def test_contiguous_is_one_chunk(self):
        cfg = KVCommConfig(ratio=0.25, selector="contiguous", layer_from=10)
        mask = np.asarray(select_layers(None, 40, cfg))
        idx = np.nonzero(mask)[0]
        assert len(idx) == 10
        assert np.all(np.diff(idx) == 1) and idx[0] == 10

    def test_contiguous_clamps(self):
        cfg = KVCommConfig(ratio=0.5, selector="contiguous", layer_from=99)
        mask = np.asarray(select_layers(None, 8, cfg))
        assert mask.sum() == 4 and mask[-1]

    def test_non_contiguous_possible(self):
        """The paper's key capability vs DroidSpeak: gaps in the subset."""
        scores = jnp.array([1.0, 0.0, 0.9, 0.0, 0.8, 0.0])
        cfg = KVCommConfig(ratio=0.5, alpha=1.0, selector="kvcomm")
        idx = np.nonzero(np.asarray(select_layers(scores, 6, cfg)))[0]
        assert list(idx) == [0, 2, 4]

    @given(st.integers(4, 64))
    @settings(max_examples=20, deadline=None)
    def test_all_selector(self, L):
        mask = select_layers(None, L, KVCommConfig(selector="all"))
        assert bool(jnp.all(mask))

    def test_selection_scores_mix(self):
        s = jnp.zeros((16,))
        out = selection_scores(s, KVCommConfig(alpha=0.25))
        pr = gaussian_prior(16)
        assert np.allclose(np.asarray(out), 0.75 * np.asarray(pr),
                           atol=1e-6)


class TestSelectionProperties:
    """Hypothesis invariants for the primitives the heterogeneous per-side
    path leans on (each side runs them over its OWN L_attn) — plus the
    edge cases they surfaced, pinned deterministically below."""

    @given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                    max_size=64),
           st.integers(-3, 80))
    @settings(max_examples=60, deadline=None)
    def test_topk_mask_cardinality(self, vals, m):
        """|mask| == clamp(m, 0, L) for ANY m, including m <= 0 and
        m >= L."""
        scores = jnp.array(vals, jnp.float32)
        L = scores.shape[0]
        mask = np.asarray(topk_mask(scores, m))
        assert mask.sum() == max(0, min(m, L))

    @given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=2,
                    max_size=32).filter(lambda v: len(set(v)) == len(v)),
           st.integers(1, 32))
    @settings(max_examples=60, deadline=None)
    def test_topk_mask_idempotent(self, vals, m):
        """Re-selecting from the mask itself (cast to scores) reproduces
        it: the mask is a fixed point of top-k at the same m."""
        scores = jnp.array(vals, jnp.float32)
        mask = topk_mask(scores, m)
        again = topk_mask(mask.astype(jnp.float32), int(mask.sum()))
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(again))

    @given(st.floats(-1e6, 1e6, allow_nan=False), st.integers(1, 64))
    @settings(max_examples=40, deadline=None)
    def test_normalize_constant_input_is_zeros(self, c, L):
        """Constant (and single-layer) inputs: no NaN, all zeros — top-k
        then degrades to index order instead of poisoning selection."""
        s = np.asarray(normalize_scores(jnp.full((L,), c, jnp.float32)))
        assert np.isfinite(s).all()
        np.testing.assert_array_equal(s, np.zeros(L))

    @given(st.integers(1, 80), st.floats(0.0, 3.0, allow_nan=False),
           st.integers(0, 1000))
    @settings(max_examples=60, deadline=None)
    def test_select_layers_bounds_any_ratio(self, L, ratio, seed):
        """1 <= |S| <= L for every ratio, including ratio=0 (m would be 0:
        clamped to one layer) and ratio > 1 (m would exceed L: clamped)."""
        cfg = KVCommConfig(ratio=ratio, selector="random", seed=seed)
        mask = np.asarray(select_layers(None, L, cfg))
        m = cfg.num_selected(L)
        assert mask.sum() == m
        assert 1 <= m <= L

    @given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=1,
                    max_size=48),
           st.integers(1, 48))
    @settings(max_examples=60, deadline=None)
    def test_interp_scores_shape_and_range(self, vals, L_new):
        """Resampled per-side scores stay inside the source's hull and
        land on the requested depth (the hetero anchor-alignment step)."""
        out = np.asarray(interp_scores(np.array(vals), L_new))
        assert out.shape == (L_new,)
        assert out.min() >= min(vals) - 1e-5
        assert out.max() <= max(vals) + 1e-5

    # -- the deterministic pins for what the properties surfaced ----------
    def test_topk_mask_m_zero_and_negative(self):
        scores = jnp.array([3.0, 1.0, 2.0])
        assert not np.asarray(topk_mask(scores, 0)).any()
        assert not np.asarray(topk_mask(scores, -5)).any()

    def test_topk_mask_m_above_L(self):
        assert np.asarray(topk_mask(jnp.array([1.0, 2.0]), 99)).all()

    def test_normalize_single_layer(self):
        np.testing.assert_array_equal(
            np.asarray(normalize_scores(jnp.array([7.5]))), [0.0])

    def test_num_selected_clamped_to_layer_count(self):
        assert KVCommConfig(ratio=2.0).num_selected(8) == 8
        assert KVCommConfig(ratio=0.0).num_selected(8) == 1

    def test_select_layers_ratio_above_one_is_all(self):
        mask = select_layers(None, 6, KVCommConfig(ratio=1.5,
                                                   selector="prior_only"))
        assert bool(jnp.all(mask))

    def test_contiguous_negative_layer_from_clamps_to_zero(self):
        cfg = KVCommConfig(ratio=0.5, selector="contiguous", layer_from=-4)
        idx = np.nonzero(np.asarray(select_layers(None, 8, cfg)))[0]
        assert list(idx) == [0, 1, 2, 3]

    def test_gaussian_prior_sigma_zero_no_nan(self):
        p = np.asarray(gaussian_prior(8, mu=4, sigma=0.0))
        assert np.isfinite(p).all()
        assert int(np.argmax(p)) == 3    # one-hot at mu (l = 4)

    def test_gaussian_prior_negative_sigma_matches_positive(self):
        """sigma enters squared: the floor must not change that."""
        np.testing.assert_array_equal(
            np.asarray(gaussian_prior(8, mu=4, sigma=-10.0)),
            np.asarray(gaussian_prior(8, mu=4, sigma=10.0)))

    def test_interp_scores_identity_and_broadcast(self):
        s = np.array([1.0, 2.0, 3.0])
        np.testing.assert_allclose(np.asarray(interp_scores(s, 3)), s)
        np.testing.assert_allclose(np.asarray(interp_scores([5.0], 4)),
                                   np.full(4, 5.0))
        np.testing.assert_allclose(np.asarray(interp_scores(s, 5)),
                                   [1.0, 1.5, 2.0, 2.5, 3.0])


class TestKendallTau:
    def test_identical_ranks(self):
        a = jnp.arange(10.0)
        assert float(kendall_tau(a, a)) == pytest.approx(1.0)

    def test_reversed_ranks(self):
        a = jnp.arange(10.0)
        assert float(kendall_tau(a, a[::-1])) == pytest.approx(-1.0)
