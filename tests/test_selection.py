"""Unit + property tests for the paper's layer-selection strategy (§3.2)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.selection import (gaussian_prior, kendall_tau,
                                  normalize_scores, select_layers,
                                  selection_scores, topk_mask)
from repro.core.types import KVCommConfig


class TestGaussianPrior:
    def test_peak_at_mu(self):
        p = gaussian_prior(32, mu=16, sigma=10)
        assert int(jnp.argmax(p)) == 15  # layer index 16 is position 15

    def test_default_mu_is_midpoint(self):
        p = gaussian_prior(28)
        assert abs(int(jnp.argmax(p)) - 13) <= 1

    def test_bounds(self):
        p = gaussian_prior(48, sigma=10)
        assert float(jnp.max(p)) <= 1.0 + 1e-6
        assert float(jnp.min(p)) > 0.0

    def test_symmetry(self):
        p = np.asarray(gaussian_prior(31, mu=16, sigma=5))
        assert np.allclose(p, p[::-1], atol=1e-6)


class TestNormalize:
    def test_range(self):
        s = normalize_scores(jnp.array([3.0, 7.0, 5.0]))
        assert float(jnp.min(s)) == 0.0 and float(jnp.max(s)) == 1.0

    def test_batch_averaged(self):
        raw = jnp.array([[1.0, 3.0], [2.0, 2.0]])  # (L=2, B=2)
        s = normalize_scores(raw)
        assert s.shape == (2,)

    @given(st.lists(st.floats(-1e3, 1e3), min_size=2, max_size=64))
    @settings(max_examples=50, deadline=None)
    def test_property_range(self, vals):
        s = np.asarray(normalize_scores(jnp.array(vals, jnp.float32)))
        assert np.all(s >= -1e-6) and np.all(s <= 1.0 + 1e-6)


class TestSelection:
    @given(st.integers(2, 80), st.floats(0.05, 1.0), st.integers(0, 10_000))
    @settings(max_examples=60, deadline=None)
    def test_topk_count_property(self, L, ratio, seed):
        cfg = KVCommConfig(ratio=ratio, selector="random", seed=seed)
        mask = np.asarray(select_layers(None, L, cfg))
        assert mask.sum() == cfg.num_selected(L) == min(
            L, max(1, int(np.ceil(ratio * L))))

    def test_kvcomm_picks_top_scores_alpha1(self):
        scores = jnp.array([0.1, 0.9, 0.3, 0.8, 0.2, 0.0])
        cfg = KVCommConfig(ratio=0.5, alpha=1.0, selector="kvcomm")
        mask = np.asarray(select_layers(scores, 6, cfg))
        assert list(np.nonzero(mask)[0]) == [1, 2, 3]

    def test_alpha0_equals_prior_only(self):
        scores = jax.random.uniform(jax.random.PRNGKey(0), (32,))
        a = select_layers(scores, 32,
                          KVCommConfig(ratio=0.3, alpha=0.0,
                                       selector="kvcomm"))
        b = select_layers(None, 32,
                          KVCommConfig(ratio=0.3, selector="prior_only"))
        assert bool(jnp.all(a == b))

    def test_contiguous_is_one_chunk(self):
        cfg = KVCommConfig(ratio=0.25, selector="contiguous", layer_from=10)
        mask = np.asarray(select_layers(None, 40, cfg))
        idx = np.nonzero(mask)[0]
        assert len(idx) == 10
        assert np.all(np.diff(idx) == 1) and idx[0] == 10

    def test_contiguous_clamps(self):
        cfg = KVCommConfig(ratio=0.5, selector="contiguous", layer_from=99)
        mask = np.asarray(select_layers(None, 8, cfg))
        assert mask.sum() == 4 and mask[-1]

    def test_non_contiguous_possible(self):
        """The paper's key capability vs DroidSpeak: gaps in the subset."""
        scores = jnp.array([1.0, 0.0, 0.9, 0.0, 0.8, 0.0])
        cfg = KVCommConfig(ratio=0.5, alpha=1.0, selector="kvcomm")
        idx = np.nonzero(np.asarray(select_layers(scores, 6, cfg)))[0]
        assert list(idx) == [0, 2, 4]

    @given(st.integers(4, 64))
    @settings(max_examples=20, deadline=None)
    def test_all_selector(self, L):
        mask = select_layers(None, L, KVCommConfig(selector="all"))
        assert bool(jnp.all(mask))

    def test_selection_scores_mix(self):
        s = jnp.zeros((16,))
        out = selection_scores(s, KVCommConfig(alpha=0.25))
        pr = gaussian_prior(16)
        assert np.allclose(np.asarray(out), 0.75 * np.asarray(pr),
                           atol=1e-6)


class TestKendallTau:
    def test_identical_ranks(self):
        a = jnp.arange(10.0)
        assert float(kendall_tau(a, a)) == pytest.approx(1.0)

    def test_reversed_ranks(self):
        a = jnp.arange(10.0)
        assert float(kendall_tau(a, a[::-1])) == pytest.approx(-1.0)
