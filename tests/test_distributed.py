"""Distribution layer: sharding rules, HLO collective parsing, and a
subprocess mini-dry-run (8 fake host devices, 2x4 mesh) exercising the same
lower+compile path the production dry-run uses."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ASSIGNED_ARCHS, get_config
from repro.distributed.sharding import _sanitize, param_spec
from repro.utils.hlo import collective_bytes

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class TestParamSpecRules:
    def test_embed(self):
        cfg = get_config("qwen1.5-110b")
        s = param_spec(cfg, "embed", (152064, 8192), dp="data", tp="model",
                       tp_size=16)
        assert s == P("model", "data")

    def test_attn_q_sharded_when_divisible(self):
        cfg = get_config("qwen1.5-110b")  # 64 heads % 16 == 0
        s = param_spec(cfg, "wq", (80, 8192, 8192), dp="data", tp="model",
                       tp_size=16)
        assert s == P(None, "data", "model")

    def test_attn_q_replicated_when_indivisible(self):
        cfg = get_config("starcoder2-7b")  # 36 heads % 16 != 0
        s = param_spec(cfg, "wq", (32, 4608, 4608), dp="data", tp="model",
                       tp_size=16)
        assert s == P(None, "data", None)

    def test_kv_heads_gate_wk(self):
        cfg = get_config("mixtral-8x22b")  # kv=8 % 16 != 0
        s = param_spec(cfg, "wk", (56, 6144, 1024), dp="data", tp="model",
                       tp_size=16)
        assert s == P(None, "data", None)

    def test_moe_expert_sharding_olmoe(self):
        cfg = get_config("olmoe-1b-7b")  # 64 experts % 16 == 0
        s = param_spec(cfg, "w_gate", (16, 64, 2048, 1024), dp="data",
                       tp="model", tp_size=16)
        assert s == P(None, "model", "data", None)

    def test_moe_expert_tensor_sharding_mixtral(self):
        cfg = get_config("mixtral-8x22b")  # 8 experts % 16 != 0
        s = param_spec(cfg, "w_gate", (56, 8, 6144, 16384), dp="data",
                       tp="model", tp_size=16)
        assert s == P(None, None, "data", "model")

    def test_sanitize_clears_indivisible(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        s = _sanitize(P("data", "model"), (7, 7), mesh)
        assert s == P("data", "model")  # axis size 1 divides everything


class TestHLOParsing:
    def test_collective_bytes_parses(self):
        txt = textwrap.dedent("""
          %x = bf16[16,128]{1,0} all-gather(%a), dimensions={0}
          %y = f32[4,4]{1,0} all-reduce(%b), to_apply=%sum
          %z = (f32[8]{0}, f32[8]{0}) all-reduce(%c, %d), to_apply=%sum
          %w = bf16[2,2]{1,0} add(%e, %f)
        """)
        out = collective_bytes(txt)
        assert out["all-gather"] == 16 * 128 * 2
        assert out["all-reduce"] == 2 * (4 * 4 * 4) + 2 * (8 * 4 * 2)
        assert out["total"] == out["all-gather"] + out["all-reduce"]

    def test_empty(self):
        assert collective_bytes("ENTRY main { ROOT %r = f32[] add(...) }")[
            "total"] == 0


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})
import json
import jax
import jax.numpy as jnp
from repro.configs.registry import get_config
from repro.distributed import sharding as shd
from repro.configs.base import InputShape
from repro.launch.specs import make_step_fn

mesh = jax.make_mesh((2, 4), ("data", "model"))
results = {{}}
for arch, shape in {combos!r}:
    cfg = get_config(arch).reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, vocab_size=128)
    sh = InputShape("t", 32 if shape != "decode" else 64, 8,
                    shape)
    fn, args = make_step_fn(cfg, sh)
    if sh.mode == "train":
        from repro.launch.dryrun import shardings_for
        in_sh = shardings_for(cfg, mesh, sh, args)
    else:
        in_sh = None
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        compiled = lowered.compile()
    from repro.utils.hlo import cost_analysis_dict
    ca = cost_analysis_dict(compiled)
    results[f"{{arch}}/{{shape}}"] = float(ca.get("flops", 0))
print("JSON" + json.dumps(results))
"""


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """Real lower+compile on an 8-device host mesh for representative archs
    across all three modes — validates the sharding rules mechanically."""
    combos = [("qwen1.5-110b", "train"), ("mixtral-8x22b", "train"),
              ("rwkv6-1.6b", "prefill"), ("zamba2-2.7b", "decode"),
              ("whisper-medium", "train"), ("gemma3-4b", "decode")]
    code = MINI_DRYRUN.format(src=os.path.abspath(SRC), combos=combos)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    payload = [l for l in proc.stdout.splitlines() if l.startswith("JSON")]
    assert payload, proc.stdout
    results = json.loads(payload[0][4:])
    assert len(results) == len(combos)
    for k, fl in results.items():
        assert fl > 0, k


def test_long500k_shape_table():
    """Every (arch x shape) combo is either runnable or an explicit
    documented skip — 40 accounted total."""
    from repro.launch.dryrun import combo_skip_reason
    n_ok, n_skip = 0, 0
    for a in ASSIGNED_ARCHS:
        for s in INPUT_SHAPES:
            if combo_skip_reason(a, s):
                n_skip += 1
            else:
                n_ok += 1
    assert n_ok + n_skip == 40
    assert n_skip == 6
