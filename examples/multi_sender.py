"""Multi-sender KVComm (paper §J): two senders each hold HALF the facts; the
receiver answers questions requiring either half by attending over both
transmitted KV prefixes concatenated along the context axis.

Each sender attaches to the session and deposits its SharedKV through the
byte-accounted transport; ``session.combined()`` merges the mailbox.

    PYTHONPATH=src python examples/multi_sender.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.comm import Agent, CommSession
from repro.core.types import KVCommConfig
from repro.data.synthetic import SyntheticTask, TaskConfig
from repro.launch.pairs import load_pair


def main() -> None:
    cfg, tok, sender_params, receiver_params = load_pair()
    session = CommSession(Agent("sender", cfg, sender_params, tok),
                          Agent("receiver", cfg, receiver_params, tok))
    task = SyntheticTask(tok, TaskConfig("retrieval", num_facts=8,
                                         seed=21))
    batch = task.batch(32)
    ctx = batch["context"]
    half = (ctx.shape[1] // 4) * 2
    c1, c2 = ctx[:, :half], ctx[:, half:]

    kvcfg = KVCommConfig(ratio=0.7, selector="prior_only")
    select = session.selection(kvcfg)

    # two mailbox senders (same weights here; disjoint knowledge)
    sender_a = session.attach_sender(session.sender, name="A")
    sender_b = session.attach_sender(session.sender, name="B")
    s1 = sender_a.send(c1, kvcfg, select=select)
    s2 = sender_b.send(c2, kvcfg, select=select)

    def acc(shared):
        out = session.receiver.prefill(batch["query"], shared, max_new=1)
        preds = session.receiver.predict_last(out.logits)
        return float(np.mean(preds == batch["answer"]))

    both = session.combined()
    print(f"sender A only (half the facts): acc {acc(s1):.3f}")
    print(f"sender B only (other half):     acc {acc(s2):.3f}")
    print(f"both senders combined (§J):     acc {acc(both):.3f}")
    print(f"transport moved {session.transport.total_bytes / 1e6:.2f} MB "
          f"over {len(session.transport.log)} transfers")


if __name__ == "__main__":
    main()
