"""Multi-sender KVComm (paper §J): two senders each hold HALF the facts; the
receiver answers questions requiring either half by attending over both
transmitted KV prefixes concatenated along the context axis.

    PYTHONPATH=src python examples/multi_sender.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax.numpy as jnp
import numpy as np

from repro import core
from repro.core.types import KVCommConfig, SharedKV
from repro.data.synthetic import SyntheticTask, TaskConfig


def main() -> None:
    from benchmarks.common import load_pair
    cfg, tok, sender_params, receiver_params = load_pair()
    task = SyntheticTask(tok, TaskConfig("retrieval", num_facts=8,
                                         seed=21))
    batch = task.batch(32)
    ctx = batch["context"]
    half = (ctx.shape[1] // 4) * 2
    c1, c2 = ctx[:, :half], ctx[:, half:]

    kvcfg = KVCommConfig(ratio=0.7, selector="prior_only")
    select = core.make_selection(cfg, kvcfg)

    def shared_for(c):
        kv, _ = core.sender_prefill(sender_params, cfg, jnp.asarray(c))
        return SharedKV(kv=kv, select=select, prefix_len=c.shape[1])

    s1, s2 = shared_for(c1), shared_for(c2)

    def acc(shared):
        out = core.receiver_prefill(receiver_params, cfg,
                                    jnp.asarray(batch["query"]), shared,
                                    max_new=1)
        preds = np.asarray(jnp.argmax(out.logits[:, -1, :], -1))
        return float(np.mean(preds == batch["answer"]))

    both = core.combine_senders([s1, s2])
    print(f"sender A only (half the facts): acc {acc(s1):.3f}")
    print(f"sender B only (other half):     acc {acc(s2):.3f}")
    print(f"both senders combined (§J):     acc {acc(both):.3f}")


if __name__ == "__main__":
    main()
