"""Heterogeneous KVComm: an 8-layer sender talking to a 12-layer receiver.

The paper's claim is that KV pairs are a viable communication medium
"across diverse model pairs"; this example exercises the axis the classic
path cannot — sender and receiver disagreeing on depth.  Selection runs
per side over each model's own layers, and a pluggable ``LayerMap`` policy
(identity-truncate / depth-proportional / score-greedy) decides which
receiver slot hosts each selected sender layer before the transport moves
exactly the mapped payload.

Expect modest task accuracy here: these two models were trained
*independently* from different random inits, so their KV spaces share no
alignment beyond the tokenizer (the paper pairs same-family checkpoints).
The demo shows the mechanics — per-side calibration, mapping, byte
accounting; structural correctness is pinned by tests/test_hetero.py.

    PYTHONPATH=src python examples/hetero_pair.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.comm import (LAYER_MAPS, Agent, CommSession, SerializedTransport)
from repro.core import kv_wire_bytes
from repro.core.types import KVCommConfig
from repro.data.synthetic import SyntheticTask, TaskConfig
from repro.launch.pairs import load_hetero_pair


def main() -> None:
    s_cfg, r_cfg, tok, s_params, r_params = load_hetero_pair()
    print(f"sender  : {s_cfg.num_layers} layers, d_model={s_cfg.d_model}")
    print(f"receiver: {r_cfg.num_layers} layers, d_model={r_cfg.d_model}")

    session = CommSession(
        Agent("sender", s_cfg, s_params, tok),
        Agent("receiver", r_cfg, r_params, tok),
        transport=SerializedTransport(wire_dtype="float16"))
    assert session.is_hetero

    task = SyntheticTask(tok, TaskConfig("retrieval", num_facts=6, seed=7))
    calib = task.batch(1)

    # per-side calibration: each model scores its OWN layers (Eq. 1 on its
    # own exported KV) — cross-model calibration would need equal depths
    s_scores = session.calibrate_side("sender", calib["context"],
                                      calib["query"], key="hetero")
    r_scores = session.calibrate_side("receiver", calib["context"],
                                      calib["query"], key="hetero")
    print(f"\nsender scores   ({s_cfg.num_layers}): "
          f"{np.round(np.asarray(s_scores), 2)}")
    print(f"receiver scores ({r_cfg.num_layers}): "
          f"{np.round(np.asarray(r_scores), 2)}")

    kvcfg = KVCommConfig(ratio=0.5, alpha=0.7)
    batch = task.batch(64)
    base = session.run("baseline", batch)
    sky = session.run("skyline", batch)
    print(f"\nbaseline acc={base.accuracy:.2f}   "
          f"skyline acc={sky.accuracy:.2f}")

    full = kv_wire_bytes(r_cfg, 64, batch["context"].shape[1] + 1,
                         r_cfg.attn_layer_count, 2)
    print(f"\n{'policy':<20} {'acc':>5} {'pairs':>5} {'bytes':>10} "
          f"{'vs full':>8}")
    for policy in sorted(LAYER_MAPS):
        res = session.run("hetero_kvcomm", batch, kvcfg=kvcfg,
                          calib_key="hetero", layer_map=policy)
        print(f"{policy:<20} {res.accuracy:>5.2f} {res.extras['M']:>5} "
              f"{res.wire_bytes:>10} {full / max(res.wire_bytes, 1):>7.1f}x")
        print(f"    {res.extras['src_layers']} -> "
              f"{res.extras['dst_layers']}")


if __name__ == "__main__":
    main()
