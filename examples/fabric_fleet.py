"""Multi-replica serving fabric demo: 2 kv_server replicas + the
affinity router, one replica killed mid-stream.

Each replica is a real threaded ``KVServer`` on its own loopback socket
with its own page pool; the router is a real ``KVClient`` per replica.
A repeated-prefix request stream routes by page affinity; at a scripted
boundary the serving replica is killed, and the stream must fail over —
re-routing to the survivor, replaying the share through the dedup
handshake, and recording the hop as a ``DegradationEvent``.

``--self-test`` asserts the fleet conformance contract and exits
non-zero on any violation (the CI fleet smoke):

  * token parity: routed completions == single-session ``serve_serial``,
    token for token (fp32 wire is lossless);
  * failover happened and was recorded as a ``DegradationEvent``;
  * the failover replay is dedup-bounded: it ships at most its own
    table, and repeats of the same context after the hop ship ZERO
    pages against the survivor's now-warm pool;
  * zero leaked pins on every replica's store once connections close.

    PYTHONPATH=src python examples/fabric_fleet.py --self-test
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.comm import Agent, CommSession
from repro.core.types import KVCommConfig
from repro.data.synthetic import SyntheticTask, TaskConfig
from repro.launch.pairs import load_pair
from repro.launch.remote_serve import KVServer
from repro.serving.fabric import (FleetEvent, FleetHarness, FleetSchedule,
                                  Replica, ReplicaSet, Router,
                                  RouterConfig)
from repro.serving.scheduler import Request, serve_serial
from repro.store import PageStore


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--contexts", type=int, default=3,
                    help="distinct contexts in the stream")
    ap.add_argument("--repeats", type=int, default=3,
                    help="requests per context (affinity traffic)")
    ap.add_argument("--max-new", type=int, default=2)
    ap.add_argument("--kill-at", type=int, default=3,
                    help="request boundary at which replica r0 dies")
    ap.add_argument("--page-len", type=int, default=16)
    ap.add_argument("--self-test", action="store_true",
                    help="assert parity + failover + dedup-bounded "
                         "replay + zero leaked pins; non-zero exit on "
                         "any violation")
    args = ap.parse_args()

    cfg, tok, sender_params, receiver_params = load_pair()
    kvcfg = KVCommConfig(ratio=0.5, selector="prior_only")
    task = SyntheticTask(tok, TaskConfig("retrieval", num_facts=6,
                                         seed=42))
    batch = task.batch(args.contexts * args.repeats)
    reqs = []
    for i in range(args.contexts * args.repeats):
        ctx = batch["context"][(i // args.repeats) * args.repeats]
        reqs.append(Request(rid=i, context=np.asarray(ctx, np.int32),
                            query=np.asarray(batch["query"][i], np.int32),
                            max_new=args.max_new))

    all_servers = []

    def build(rid, port=0):
        srv = KVServer(Agent(f"recv-{rid}", cfg, receiver_params, tok),
                       port=port, store=PageStore(page_len=args.page_len))
        all_servers.append(srv)
        return srv

    servers, replicas = {}, ReplicaSet()
    for rid in ("r0", "r1"):
        servers[rid] = build(rid)
        replicas.add(Replica(rid, servers[rid].host, servers[rid].port,
                             connect_timeout_s=0.25))
    schedule = FleetSchedule([FleetEvent(args.kill_at, "kill", "r0")])
    harness = FleetHarness(replicas, servers, build, schedule)
    harness.start()
    router = Router(Agent("sender", cfg, sender_params, tok), kvcfg,
                    replicas,
                    config=RouterConfig(wire_dtype="float32",
                                        page_len=args.page_len))
    try:
        comps, metrics = router.run(reqs, before=harness.before)
    finally:
        router.close()
        harness.stop()

    print(f"served {metrics['requests']} requests: "
          f"{metrics['served']} (+{metrics['local']} local), "
          f"{metrics['failovers']} failover(s), page hit-rate "
          f"{metrics['page_hit_rate']:.3f}")
    for ev in router.degradations:
        print(f"  {ev}")

    # the single-session serial reference the fleet must match
    ref_sess = CommSession(Agent("s-ref", cfg, sender_params, tok),
                           Agent("r-ref", cfg, receiver_params, tok))
    ref, _ = serve_serial(ref_sess, reqs, kvcfg)
    parity = all(np.array_equal(c.tokens, r.tokens)
                 for c, r in zip(comps, ref))
    print(f"token parity vs serve_serial: {parity}")

    routes = {r.rid: r for r in router.routes}
    hops = [r.rid for r in router.routes if r.hops]
    hop = min(hops) if hops else None
    replay_bounded = hop is not None and \
        routes[hop].pages_sent <= routes[hop].pages_total
    # dedup bound, part 2: later REPEATS of the hop request's context
    # (same rid // repeats group) find its pages already resident on the
    # survivor — they must ship zero.  Later *distinct* contexts still
    # ship their own pages; that is not a replay.
    post_hop_zero = hop is not None and all(
        routes[r].pages_sent == 0 for r in range(hop + 1, len(reqs))
        if r // args.repeats == hop // args.repeats and r in routes)
    if hop is not None:
        print(f"failover at rid {hop}: replayed "
              f"{routes[hop].pages_sent}/{routes[hop].pages_total} "
              f"pages; same-context repeats after the hop shipped "
              f"zero: {post_hop_zero}")
    pins_ok = all(s.store.stats().pinned_bytes == 0
                  for s in all_servers if s.store is not None)
    print(f"zero leaked pins: {pins_ok}")

    if args.self_test:
        failures = []
        if not parity:
            failures.append("routed output diverged from serve_serial")
        if hop is None:
            failures.append("kill schedule produced no failover")
        if router.degradations == []:
            failures.append("failover left no DegradationEvent")
        if not replay_bounded:
            failures.append("failover replay was not dedup-bounded")
        if not post_hop_zero:
            failures.append("post-failover repeats shipped pages")
        if not pins_ok:
            failures.append("a replica store leaked pinned bytes")
        if failures:
            for f in failures:
                print(f"SELF-TEST FAILED: {f}", file=sys.stderr)
            return 1
        print("SELF-TEST PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
