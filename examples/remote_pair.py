"""Two-process KVComm: sender and receiver in SEPARATE processes, selected
KV crossing a real TCP socket through the framed remote codec.

The parent process plays the sender (and runs the in-process
``InMemoryTransport`` reference); a spawned child process runs
``repro.launch.remote_serve server`` with the receiver model.  The same
calibrated, frozen layer selection drives both paths, so with a lossless
fp32 wire the remote predictions must be IDENTICAL to the in-process ones —
``--self-test`` asserts exactly that, plus the payload-bytes-vs-analytics
equality, and exits non-zero on any mismatch (the CI socket smoke test).

    PYTHONPATH=src python examples/remote_pair.py --self-test
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.comm import Agent, CommSession, InMemoryTransport
from repro.core import kv_wire_bytes
from repro.core.types import KVCommConfig
from repro.data.synthetic import SyntheticTask, TaskConfig
from repro.launch.pairs import load_pair
from repro.launch.remote_serve import KVClient

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

ITEMSIZE = {"float32": 4, "float16": 2, "bfloat16": 2}


def spawn_server() -> "tuple[subprocess.Popen, int]":
    """Start the receiver process; returns (proc, bound port)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.remote_serve", "server",
         "--port", "0"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)
    while True:
        line = proc.stdout.readline()
        if not line:
            raise RuntimeError("server exited before announcing its port")
        print(f"[server] {line.rstrip()}")
        if line.startswith("PORT "):
            return proc, int(line.split()[1])


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--ratio", type=float, default=0.5)
    ap.add_argument("--wire-dtype", default="float32",
                    choices=sorted(ITEMSIZE),
                    help="fp32 is lossless: remote == in-process exactly")
    ap.add_argument("--self-test", action="store_true",
                    help="assert remote == in-process and bytes == "
                         "analytics; non-zero exit on mismatch")
    args = ap.parse_args()

    # the parent loads (and, cold, quick-trains + caches) the pair FIRST,
    # so the child restores the cached checkpoint instead of retraining
    cfg, tok, sender_params, receiver_params = load_pair()
    task = SyntheticTask(tok, TaskConfig("retrieval", num_facts=6, seed=42))
    batch = task.batch(args.requests)
    kvcfg = KVCommConfig(ratio=args.ratio, alpha=0.7)

    # in-process reference: calibrate once, freeze the selection, share
    # through InMemoryTransport and generate
    session = CommSession(Agent("sender", cfg, sender_params, tok),
                          Agent("receiver", cfg, receiver_params, tok),
                          InMemoryTransport())
    calib = task.batch(1)
    session.calibrate(calib["context"], calib["query"], key="retrieval6")
    select = session.selection(kvcfg, key="retrieval6")
    shared, _ = session.share(batch["context"], kvcfg, key="retrieval6")
    ref_toks = session.generate(batch["query"], shared,
                                max_new=args.max_new)
    print(f"in-process preds : {ref_toks[:, 0]}")

    # remote run: same frozen selection, KV over a real socket
    proc, port = spawn_server()
    try:
        client = KVClient.connect("127.0.0.1", port)
        try:
            sent = client.share(session.sender, batch["context"], kvcfg,
                                select, wire_dtype=args.wire_dtype)
            remote_toks = client.generate(batch["query"],
                                          max_new=args.max_new)
        finally:
            client.close()
    finally:
        try:
            proc.wait(timeout=30)
        except subprocess.TimeoutExpired:
            proc.kill()
    print(f"remote preds     : {remote_toks[:, 0]}")

    M = int(np.asarray(select).sum())
    expect = kv_wire_bytes(cfg, args.requests, shared.prefix_len, M,
                           itemsize=ITEMSIZE[args.wire_dtype])
    print(f"payload bytes    : {sent} (analytic {expect}, "
          f"{M}/{cfg.attn_layer_count} layers, {args.wire_dtype} wire)")

    match = bool(np.array_equal(ref_toks, remote_toks))
    bytes_ok = sent == expect
    print(f"predictions match: {match}; bytes match analytics: {bytes_ok}")
    if args.self_test:
        if args.wire_dtype == "float32" and not match:
            print("SELF-TEST FAILED: lossless remote run diverged",
                  file=sys.stderr)
            return 1
        if not bytes_ok:
            print("SELF-TEST FAILED: measured bytes != analytic bytes",
                  file=sys.stderr)
            return 1
        print("SELF-TEST PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
