"""Distributed KV residency (DESIGN.md §3, beyond-paper): the receiver decodes
against a KV cache SHARDED across devices, combining per-shard flash-decode
partials with the LSE rule instead of ever gathering the cache.

On this 1-CPU container the shards are simulated sequentially; on a pod the
identical partials/combine code runs under ``shard_map`` with the cache
sequence-sharded over the mesh (see ``repro.launch.dryrun`` long_500k).

    PYTHONPATH=src python examples/distributed_decode.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def main() -> None:
    key = jax.random.PRNGKey(0)
    B, Hq, Hkv, D = 2, 8, 2, 64
    S_total, n_shards = 4096, 8
    per = S_total // n_shards

    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, D))
    k = jax.random.normal(ks[1], (B, S_total, Hkv, D))
    v = jax.random.normal(ks[2], (B, S_total, Hkv, D))

    # ground truth: monolithic decode over the whole cache
    full = ref.decode_reference(q, k, v, kv_len=S_total)

    # distributed: each "device" computes partials over ITS shard only
    os_, ms_, ls_ = [], [], []
    for i in range(n_shards):
        sl = slice(i * per, (i + 1) * per)
        o, m, l = ops.decode_attention_partials(q, k[:, sl], v[:, sl],
                                                per, blk_k=128)
        os_.append(o), ms_.append(m), ls_.append(l)
    combined = ref.combine_decode_partials(
        jnp.stack(os_), jnp.stack(ms_), jnp.stack(ls_))

    err = float(jnp.max(jnp.abs(combined - full)))
    print(f"cache {S_total} tokens across {n_shards} shards")
    print(f"per-shard partial shapes: o{tuple(os_[0].shape)} "
          f"m{tuple(ms_[0].shape)} l{tuple(ls_[0].shape)}")
    print(f"LSE-combined vs monolithic decode: max |err| = {err:.2e}")
    wire = sum(x.size * 4 for x in (os_[0], ms_[0], ls_[0]))
    kv_wire = per * Hkv * D * 2 * 4
    print(f"bytes moved per shard: {wire} (vs {kv_wire} to gather its KV "
          f"shard -> {kv_wire / wire:.0f}x saving)")
    assert err < 1e-4


if __name__ == "__main__":
    main()
