"""KVComm quickstart: one sender, one receiver, one question — on the
``repro.comm`` stack.

Builds the trained pair (or quick-trains a stand-in), then walks the
communication round explicitly through the four API concepts:

  Agent      — sender/receiver models with prefill/decode/export_kv
  Transport  — SerializedTransport: the fp16 wire payload is actually
               materialized and its bytes measured
  selection  — calibrate -> Gaussian-prior-mixed scores -> top-M layers
  CommSession— ties them together; ``session.run("kvcomm", ...)`` is the
               one-line version of everything below

    PYTHONPATH=src python examples/quickstart.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.comm import Agent, CommSession, SerializedTransport
from repro.core import kv_wire_bytes
from repro.core.types import KVCommConfig
from repro.data.synthetic import SyntheticTask, TaskConfig
from repro.launch.pairs import load_pair


def main() -> None:
    cfg, tok, sender_params, receiver_params = load_pair()
    session = CommSession(
        Agent("sender", cfg, sender_params, tok),
        Agent("receiver", cfg, receiver_params, tok),
        transport=SerializedTransport(wire_dtype="float16"))

    task = SyntheticTask(tok, TaskConfig("retrieval", num_facts=6, seed=7))
    sample = task.batch(1)
    print(f"context tokens : {sample['context'][0]}")
    print(f"query tokens   : {sample['query'][0]}")
    print(f"gold answer    : {sample['answer'][0]}")

    # 1. calibrate: one sample (paper §H). The sender prefills the context
    #    ONCE; the receiver measures Eq.(1) attention mass per layer.
    scores = session.calibrate(sample["context"], sample["query"],
                               key="quickstart")
    print(f"\nattention importance scores: "
          f"{np.round(np.asarray(scores), 3)}")

    # 2. select top-M layers under the Gaussian prior, frozen for the task
    kvcfg = KVCommConfig(ratio=0.5, alpha=0.7)
    select = session.selection(kvcfg, scores=scores, key="quickstart")
    print(f"selected layers ({kvcfg.ratio:.0%}): "
          f"{np.nonzero(np.asarray(select))[0]}")

    # 3. share: sender prefill -> transport. The SerializedTransport
    #    gathers exactly the selected layers, casts to fp16, and counts
    #    the payload's real bytes.
    shared, _ = session.share(sample["context"], kvcfg, key="quickstart")
    rec = session.transport.last
    L = cfg.attn_layer_count
    print(f"wire bytes: {rec.n_bytes} ({rec.layers} layers, "
          f"{rec.wire_dtype} wire; full sharing would be "
          f"{kv_wire_bytes(cfg, 1, shared.prefix_len, L, 2)})")

    # 4. the receiver answers, streaming one token per decode step
    first = next(iter(session.stream(sample["query"], shared, max_new=1)))
    pred = int(first[0])
    print(f"\nreceiver prediction: {pred} "
          f"({'CORRECT' if pred == sample['answer'][0] else 'wrong'})")

    # ... or in one line, with byte/FLOP/latency accounting attached:
    r = session.run("kvcomm", task.batch(16), kvcfg=kvcfg,
                    calib_key="quickstart")
    print(f"session.run('kvcomm'): acc={r.accuracy:.2f} "
          f"bytes={r.wire_bytes} latency={r.latency_s * 1e3:.0f}ms")


if __name__ == "__main__":
    main()
