"""KVComm quickstart: one sender, one receiver, one question.

Builds a tiny untrained pair (or the trained checkpoints if you ran
``train_comm_pair.py``), walks the full protocol explicitly — sender prefill
-> calibration -> layer selection -> transmission -> receiver prefill ->
decode — and prints what moved over the wire.

    PYTHONPATH=src python examples/quickstart.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.core.types import KVCommConfig
from repro.data.synthetic import SyntheticTask, TaskConfig
from repro.data.tokenizer import SymbolTokenizer


def main() -> None:
    from benchmarks.common import load_pair
    cfg, tok, sender_params, receiver_params = load_pair()

    task = SyntheticTask(tok, TaskConfig("retrieval", num_facts=6, seed=7))
    sample = task.batch(1)
    print(f"context tokens : {sample['context'][0]}")
    print(f"query tokens   : {sample['query'][0]}")
    print(f"gold answer    : {sample['answer'][0]}")

    # 1. sender prefills the context ONCE (no decoding!)
    kv, states = core.sender_prefill(sender_params, cfg,
                                     jnp.asarray(sample["context"]))
    L = cfg.attn_layer_count
    print(f"\nsender produced KV for {L} layers, "
          f"shape per layer {tuple(kv['k'].shape[1:])}")

    # 2. calibrate: receiver measures Eq.(1) attention mass per layer
    scores = core.calibrate(receiver_params, cfg,
                            jnp.asarray(sample["query"]), kv)
    print(f"attention importance scores: {np.round(np.asarray(scores), 3)}")

    # 3. select top-M layers under the Gaussian prior
    kvcfg = KVCommConfig(ratio=0.5, alpha=0.7)
    select = core.make_selection(cfg, kvcfg, scores)
    print(f"selected layers ({kvcfg.ratio:.0%}): "
          f"{np.nonzero(np.asarray(select))[0]}")

    # 4. transmit exactly those layers
    channel = core.Channel()
    shared = channel.send_kv(cfg, kvcfg, kv, select)
    print(f"wire bytes: {channel.total_bytes} "
          f"(full sharing would be "
          f"{core.kv_wire_bytes(cfg, 1, shared.prefix_len, L, 4)})")

    # 5. receiver answers
    toks, _ = core.generate(receiver_params, cfg,
                            jnp.asarray(sample["query"]), shared, max_new=1)
    pred = int(jnp.argmax(core.receiver_prefill(
        receiver_params, cfg, jnp.asarray(sample["query"]), shared,
        max_new=1).logits[:, -1, :], -1)[0])
    print(f"\nreceiver prediction: {pred} "
          f"({'CORRECT' if pred == sample['answer'][0] else 'wrong'})")


if __name__ == "__main__":
    main()
