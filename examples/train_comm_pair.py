"""Train the sender/receiver model pair for the communication experiments.

Mirrors the paper's setup at CPU scale: one base model trained from scratch
on a mixture of synthetic contextual tasks (retrieval / multihop / decision —
the Countries / HotpotQA / Tipsheets analogues), then two divergent
fine-tunes of that base become M_s and M_r ("fine-tuned versions of the same
base LLM", paper §2.1).

Checkpoints land in experiments/ckpt/{base,sender,receiver}.npz and are
consumed by every communication benchmark.

Run:  PYTHONPATH=src python examples/train_comm_pair.py [--steps 6000]
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data.pipeline import mixed_lm_iter, synthetic_lm_iter
from repro.data.synthetic import SyntheticTask, TaskConfig
from repro.data.tokenizer import SymbolTokenizer
from repro.training import checkpoint
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import TrainState, init_train_state, train

CKPT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "ckpt")


def pair_tokenizer() -> SymbolTokenizer:
    return SymbolTokenizer(num_entities=32, num_attributes=16)


def pair_config():
    """Tiny Llama-3.2-family stand-in: 8 layers so layer selection has room
    to matter, float32 for CPU numerics."""
    tok = pair_tokenizer()
    return dataclasses.replace(
        get_config("llama3.2-3b-pair"),
        num_layers=8, d_model=192, d_ff=512, num_heads=6, num_kv_heads=6,
        head_dim=32, vocab_size=tok.vocab_size, dtype="float32",
        remat=False, tie_embeddings=False)


def task_suite(tok, seed=0):
    return [
        SyntheticTask(tok, TaskConfig("retrieval", num_facts=4, seed=seed)),
        SyntheticTask(tok, TaskConfig("retrieval", num_facts=6,
                                      seed=seed + 1)),
        SyntheticTask(tok, TaskConfig("retrieval", num_facts=8,
                                      seed=seed + 2)),
        SyntheticTask(tok, TaskConfig("multihop", num_facts=6, hops=2,
                                      seed=seed + 3)),
        SyntheticTask(tok, TaskConfig("decision", num_options=3,
                                      seed=seed + 4)),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=6000)
    ap.add_argument("--ft-steps", type=int, default=600)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    args = ap.parse_args()

    tok = pair_tokenizer()
    cfg = pair_config()
    tasks = task_suite(tok, seed=0)
    os.makedirs(CKPT_DIR, exist_ok=True)

    # ---- base model ----
    base_path = os.path.join(CKPT_DIR, "base")
    it = mixed_lm_iter(tasks, args.batch, seed=0)
    opt = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=args.steps // 20)
    state = train(cfg, opt, it, steps=args.steps,
                  key=jax.random.PRNGKey(0), log_every=250)
    checkpoint.save(base_path, state.params, {"role": "base"})
    print(f"saved {base_path}")

    # ---- divergent fine-tunes -> sender / receiver ----
    ft_opt = OptimizerConfig(lr=args.lr / 4, total_steps=args.ft_steps,
                             warmup_steps=20)
    for role, seed in (("sender", 101), ("receiver", 202)):
        ft_tasks = task_suite(tok, seed=seed)
        it = mixed_lm_iter(ft_tasks, args.batch, seed=seed)
        # copy: the jitted train step donates its input state, so each
        # fine-tune must start from a fresh buffer of the base params
        base_params = jax.tree.map(jnp.copy, state.params)
        st = TrainState(params=base_params,
                        opt=init_train_state(cfg,
                                             jax.random.PRNGKey(seed)).opt)
        st = train(cfg, ft_opt, it, steps=args.ft_steps, state=st,
                   log_every=200)
        checkpoint.save(os.path.join(CKPT_DIR, role), st.params,
                        {"role": role})
        print(f"saved {role}")


if __name__ == "__main__":
    main()
