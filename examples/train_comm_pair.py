"""Train the sender/receiver model pair for the communication experiments.

Mirrors the paper's setup at CPU scale: one base model trained from scratch
on a mixture of synthetic contextual tasks (retrieval / multihop / decision —
the Countries / HotpotQA / Tipsheets analogues), then two divergent
fine-tunes of that base become M_s and M_r ("fine-tuned versions of the same
base LLM", paper §2.1).

Checkpoints land in experiments/ckpt/{base,sender,receiver}.npz and are
consumed by every communication benchmark.

Run:  PYTHONPATH=src python examples/train_comm_pair.py [--steps 6000]
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.data.pipeline import mixed_lm_iter
# pair definitions live in the package so serving / benchmarks / examples
# share one source of truth (no sys.path games)
from repro.launch.pairs import (CKPT_DIR, pair_config, pair_tokenizer,
                                task_suite)
from repro.training import checkpoint
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import TrainState, init_train_state, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=6000)
    ap.add_argument("--ft-steps", type=int, default=600)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    args = ap.parse_args()

    tok = pair_tokenizer()
    cfg = pair_config()
    tasks = task_suite(tok, seed=0)
    os.makedirs(CKPT_DIR, exist_ok=True)

    # ---- base model ----
    base_path = os.path.join(CKPT_DIR, "base")
    it = mixed_lm_iter(tasks, args.batch, seed=0)
    opt = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=args.steps // 20)
    state = train(cfg, opt, it, steps=args.steps,
                  key=jax.random.PRNGKey(0), log_every=250)
    checkpoint.save(base_path, state.params, {"role": "base"})
    print(f"saved {base_path}")

    # ---- divergent fine-tunes -> sender / receiver ----
    ft_opt = OptimizerConfig(lr=args.lr / 4, total_steps=args.ft_steps,
                             warmup_steps=20)
    for role, seed in (("sender", 101), ("receiver", 202)):
        ft_tasks = task_suite(tok, seed=seed)
        it = mixed_lm_iter(ft_tasks, args.batch, seed=seed)
        # copy: the jitted train step donates its input state, so each
        # fine-tune must start from a fresh buffer of the base params
        base_params = jax.tree.map(jnp.copy, state.params)
        st = TrainState(params=base_params,
                        opt=init_train_state(cfg,
                                             jax.random.PRNGKey(seed)).opt)
        st = train(cfg, ft_opt, it, steps=args.ft_steps, state=st,
                   log_every=200)
        checkpoint.save(os.path.join(CKPT_DIR, role), st.params,
                        {"role": role})
        print(f"saved {role}")


if __name__ == "__main__":
    main()
